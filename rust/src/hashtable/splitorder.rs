//! Split-order hash table (§VII variant 3, "SPO") after Shalev & Shavit,
//! with the paper's locking twist: read-write locks on the whole table (for
//! resizing) and per slot, instead of the original lock-free CAS list.
//!
//! One shared linked list holds every node sorted by *split-order key*
//! (bit-reversed hash; regular nodes additionally set the pre-reversal MSB,
//! so after reversal their LSB is 1 and slot dummies — reversed slot
//! indices, LSB 0 — sort strictly first in their region). Slots point at
//! dummy nodes. Resizing just doubles the active slot count: **no data
//! migration** — new slots are initialized lazily by splicing a dummy into
//! the parent slot's region on first touch (recursive parent walk, the
//! cache-miss source Table VI measures).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::mem::{ArenaOptions, PoolStats};
use crate::skiplist::node::{NodeArena, NodeRef, SENTINEL};
use crate::sync::RwSpinLock;

use super::hash::{hash_key, so_dummy_key, so_parent, so_regular_key};
use super::traits::ConcurrentMap;

/// "uninitialized slot" marker (a NodeRef can never be all-ones: index
/// u32::MAX is never allocated by the arena sizes we use).
const UNINIT: u64 = u64::MAX;

/// Cache-behaviour proxy counters for Table VI: the one-level table's lazy
/// slot initialization chases far-apart parent slots; the two-level variant
/// keeps chains short and local.
#[derive(Debug, Default, Clone)]
pub struct SpoStats {
    pub init_parent_hops: u64,
    pub walk_steps: u64,
    pub resizes: u64,
}

#[derive(Default)]
struct AtomicSpoStats {
    init_parent_hops: AtomicU64,
    walk_steps: AtomicU64,
    resizes: AtomicU64,
}

/// Split-order table. `seed` initial slots, growing by doubling while
/// `len > active_slots * max_collisions`.
pub struct SpoHashMap {
    arena: NodeArena,
    /// head of the shared list = dummy of slot 0 (kept for list-order tests).
    #[cfg_attr(not(test), allow(dead_code))]
    pub(crate) head: NodeRef,
    slots: Box<[AtomicU64]>,
    locks: Box<[RwSpinLock]>,
    active: AtomicUsize,
    resize_lock: RwSpinLock,
    max_collisions: usize,
    len: AtomicU64,
    stats: AtomicSpoStats,
}

impl SpoHashMap {
    /// The paper's defaults: seed 8192 slots, 16 max collisions.
    pub fn new() -> SpoHashMap {
        Self::with_config(8192, 16, 1 << 17, 1 << 22)
    }

    /// `seed` initial active slots, growth capped at `max_slots`, arena
    /// capacity `capacity` nodes.
    pub fn with_config(seed: usize, max_collisions: usize, max_slots: usize, capacity: usize) -> SpoHashMap {
        Self::with_config_on(seed, max_collisions, max_slots, capacity, ArenaOptions::default())
    }

    /// Like [`SpoHashMap::with_config`] with explicit arena placement (the
    /// paper gives each first-level slot its own memory manager; per-shard
    /// tables home it on the shard's NUMA node).
    pub fn with_config_on(
        seed: usize,
        max_collisions: usize,
        max_slots: usize,
        capacity: usize,
        opts: ArenaOptions,
    ) -> SpoHashMap {
        assert!(seed.is_power_of_two() && max_slots.is_power_of_two() && seed <= max_slots);
        let arena = NodeArena::for_capacity(capacity, opts);
        // dummy for slot 0 heads the list.
        let head = arena.alloc(so_dummy_key(0), SENTINEL, SENTINEL, 0, 0);
        let slots: Box<[AtomicU64]> = (0..max_slots).map(|_| AtomicU64::new(UNINIT)).collect();
        slots[0].store(head, Ordering::Release);
        SpoHashMap {
            arena,
            head,
            slots,
            locks: (0..max_slots).map(|_| RwSpinLock::new()).collect(),
            active: AtomicUsize::new(seed),
            resize_lock: RwSpinLock::new(),
            max_collisions,
            len: AtomicU64::new(0),
            stats: AtomicSpoStats::default(),
        }
    }

    pub fn stats(&self) -> SpoStats {
        SpoStats {
            init_parent_hops: self.stats.init_parent_hops.load(Ordering::Relaxed),
            walk_steps: self.stats.walk_steps.load(Ordering::Relaxed),
            resizes: self.stats.resizes.load(Ordering::Relaxed),
        }
    }

    pub fn active_slots(&self) -> usize {
        self.active.load(Ordering::Acquire)
    }

    /// §V arena accounting (allocs/recycled/capacity/locality).
    pub fn mem_stats(&self) -> PoolStats {
        self.arena.stats()
    }

    /// Ensure `slot`'s dummy exists; recursively initializes parents.
    /// Caller holds the table read lock; this takes parent slot write locks.
    fn ensure_slot(&self, slot: usize) -> NodeRef {
        let cur = self.slots[slot].load(Ordering::Acquire);
        if cur != UNINIT {
            return cur;
        }
        let parent = so_parent(slot);
        // distance-weighted: the cache cost Table VI measures is parent
        // slots being FAR APART in the slot array (flat table: distance up
        // to active/2; hierarchical: bounded by the small table size).
        self.stats
            .init_parent_hops
            .fetch_add((slot - parent) as u64 + 1, Ordering::Relaxed);
        let pdummy = self.ensure_slot(parent);
        // splice dummy(slot) into the parent's region under its lock
        let plock = &self.locks[parent];
        plock.lock();
        // re-check: someone may have initialized it while we waited
        let cur = self.slots[slot].load(Ordering::Acquire);
        if cur != UNINIT {
            plock.unlock();
            return cur;
        }
        let dkey = so_dummy_key(slot as u64);
        // find insert position from the parent's dummy
        let (mut pred, mut steps) = (pdummy, 0u64);
        loop {
            let pn = self.arena.node(pred);
            let (_, next) = pn.key_next();
            if next == SENTINEL {
                break;
            }
            let (nk, _) = self.arena.node(next).key_next();
            if nk >= dkey {
                break;
            }
            pred = next;
            steps += 1;
        }
        self.stats.walk_steps.fetch_add(steps, Ordering::Relaxed);
        let prn = self.arena.node(pred);
        let (pk, pnext) = prn.key_next();
        let dummy = self.arena.alloc(dkey, pnext, SENTINEL, 0, 0);
        prn.set_key_next(pk, dummy);
        self.slots[slot].store(dummy, Ordering::Release);
        plock.unlock();
        dummy
    }

    /// Double the active slot count if occupancy exceeds the threshold.
    fn maybe_resize(&self) {
        let n = self.active.load(Ordering::Acquire);
        if (self.len() as usize) <= n * self.max_collisions || n * 2 > self.slots.len() {
            return;
        }
        // exclusive table lock; the operation itself is O(1)
        self.resize_lock.lock();
        let n = self.active.load(Ordering::Acquire);
        if (self.len() as usize) > n * self.max_collisions && n * 2 <= self.slots.len() {
            self.active.store(n * 2, Ordering::Release);
            self.stats.resizes.fetch_add(1, Ordering::Relaxed);
        }
        self.resize_lock.unlock();
    }

    /// slot index for hash `h` under the current active count.
    #[inline]
    fn slot_index(&self, h: u64) -> usize {
        (h & (self.active.load(Ordering::Acquire) as u64 - 1)) as usize
    }

    /// Walk the slot region for `sokey`; returns (pred, Option<node>) where
    /// node is the exact match. Caller holds the slot lock.
    fn locate(&self, dummy: NodeRef, sokey: u64) -> (NodeRef, Option<NodeRef>) {
        let mut pred = dummy;
        let mut steps = 0u64;
        loop {
            let (_, next) = self.arena.node(pred).key_next();
            if next == SENTINEL {
                self.stats.walk_steps.fetch_add(steps, Ordering::Relaxed);
                return (pred, None);
            }
            let (nk, _) = self.arena.node(next).key_next();
            if nk == sokey {
                self.stats.walk_steps.fetch_add(steps, Ordering::Relaxed);
                return (pred, Some(next));
            }
            if nk > sokey {
                self.stats.walk_steps.fetch_add(steps, Ordering::Relaxed);
                return (pred, None);
            }
            pred = next;
            steps += 1;
        }
    }
}

impl Default for SpoHashMap {
    fn default() -> Self {
        Self::new()
    }
}

impl ConcurrentMap for SpoHashMap {
    fn insert(&self, key: u64, value: u64) -> bool {
        let h = hash_key(key);
        let sokey = so_regular_key(h);
        self.resize_lock.lock_shared();
        let slot = self.slot_index(h);
        let dummy = self.ensure_slot(slot);
        let lock = &self.locks[slot];
        lock.lock();
        let (pred, found) = self.locate(dummy, sokey);
        let ok = if found.is_some() {
            false
        } else {
            let prn = self.arena.node(pred);
            let (pk, pnext) = prn.key_next();
            // The split-order key drops one hash bit (`h | MSB` before the
            // reversal), so the original key is NOT recoverable from the
            // node — stash it in `bottom`, which flat list nodes never use.
            let node = self.arena.alloc(sokey, pnext, key, value, 0);
            prn.set_key_next(pk, node);
            true
        };
        lock.unlock();
        self.resize_lock.unlock_shared();
        if ok {
            self.len.fetch_add(1, Ordering::Relaxed);
            self.maybe_resize();
        }
        ok
    }

    fn get(&self, key: u64) -> Option<u64> {
        let h = hash_key(key);
        let sokey = so_regular_key(h);
        self.resize_lock.lock_shared();
        let slot = self.slot_index(h);
        let dummy = self.ensure_slot(slot);
        let lock = &self.locks[slot];
        lock.lock_shared();
        let (_, found) = self.locate(dummy, sokey);
        let r = found.map(|n| self.arena.node(n).cold.value.load(Ordering::Relaxed));
        lock.unlock_shared();
        self.resize_lock.unlock_shared();
        r
    }

    fn erase(&self, key: u64) -> bool {
        let h = hash_key(key);
        let sokey = so_regular_key(h);
        self.resize_lock.lock_shared();
        let slot = self.slot_index(h);
        let dummy = self.ensure_slot(slot);
        let lock = &self.locks[slot];
        lock.lock();
        let (pred, found) = self.locate(dummy, sokey);
        let ok = if let Some(node) = found {
            let prn = self.arena.node(pred);
            let (pk, _) = prn.key_next();
            let nn = self.arena.node(node);
            let (_, nnext) = nn.key_next();
            prn.set_key_next(pk, nnext);
            nn.cold.mark.store(true, Ordering::Release);
            self.arena.retire(node);
            true
        } else {
            false
        };
        lock.unlock();
        self.resize_lock.unlock_shared();
        if ok {
            self.len.fetch_sub(1, Ordering::Relaxed);
        }
        ok
    }

    fn len(&self) -> u64 {
        self.len.load(Ordering::Relaxed)
    }

    fn for_each(&self, f: &mut dyn FnMut(u64, u64)) {
        // Every op holds the table lock shared; taking it exclusive
        // quiesces writers so the one-pass list walk is a true snapshot.
        // The callback runs only AFTER the lock is dropped: it may panic or
        // re-enter this map, and the manual spinlock would wedge the whole
        // table in either case.
        self.resize_lock.lock();
        let mut pairs = Vec::new();
        let mut cur = self.head;
        while cur != SENTINEL {
            let n = self.arena.node(cur);
            let (sokey, next) = n.key_next();
            if sokey & 1 == 1 {
                // regular node (reversed MSB): original key stashed in
                // `bottom` at insert time
                pairs.push((n.hot.bottom.load(Ordering::Acquire), n.cold.value.load(Ordering::Relaxed)));
            }
            cur = next;
        }
        self.resize_lock.unlock();
        for (k, v) in pairs {
            f(k, v);
        }
    }

    fn name(&self) -> &'static str {
        "splitorder"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn small() -> SpoHashMap {
        SpoHashMap::with_config(4, 4, 1 << 10, 1 << 14)
    }

    #[test]
    fn basic() {
        let m = small();
        assert!(m.insert(1, 10));
        assert!(!m.insert(1, 11));
        assert_eq!(m.get(1), Some(10));
        assert!(m.erase(1));
        assert!(!m.erase(1));
        assert_eq!(m.get(1), None);
    }

    #[test]
    fn grows_without_migration_and_keeps_contents() {
        let m = small();
        for k in 0..2_000u64 {
            assert!(m.insert(k, k * 3));
        }
        assert!(m.stats().resizes > 0, "table must resize");
        assert!(m.active_slots() > 4);
        for k in 0..2_000u64 {
            assert_eq!(m.get(k), Some(k * 3), "key {k} lost across resizes");
        }
    }

    #[test]
    fn shared_list_is_sorted_by_split_order() {
        let m = small();
        for k in 0..500u64 {
            m.insert(k, k);
        }
        // walk the whole list: split-order keys must strictly increase
        let mut cur = m.head;
        let mut prev: Option<u64> = None;
        let mut regulars = 0;
        while cur != SENTINEL {
            let (k, nx) = m.arena.node(cur).key_next();
            if let Some(p) = prev {
                assert!(k > p, "split-order keys must increase: {p:#x} -> {k:#x}");
            }
            if k & 1 == 1 {
                regulars += 1; // regular nodes have LSB 1 after reversal
            }
            prev = Some(k);
            cur = nx;
        }
        assert_eq!(regulars, 500);
    }

    #[test]
    fn oracle_sequential() {
        let m = small();
        let mut oracle = BTreeMap::new();
        let mut rng = Rng::new(23);
        for _ in 0..20_000 {
            let k = rng.below(800);
            match rng.below(3) {
                0 => {
                    let fresh = !oracle.contains_key(&k);
                    assert_eq!(m.insert(k, k + 5), fresh);
                    oracle.entry(k).or_insert(k + 5);
                }
                1 => assert_eq!(m.erase(k), oracle.remove(&k).is_some()),
                _ => assert_eq!(m.get(k), oracle.get(&k).copied()),
            }
        }
        assert_eq!(m.len() as usize, oracle.len());
    }

    #[test]
    fn concurrent_inserts_through_resize() {
        let m = Arc::new(SpoHashMap::with_config(4, 4, 1 << 12, 1 << 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    let k = t * 1_000_000 + i;
                    assert!(m.insert(k, k));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.len(), 8_000);
        for t in 0..4u64 {
            for i in (0..2_000u64).step_by(101) {
                assert_eq!(m.get(t * 1_000_000 + i), Some(t * 1_000_000 + i));
            }
        }
    }

    #[test]
    fn concurrent_mixed() {
        let m = Arc::new(small());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t + 77);
                for _ in 0..4_000 {
                    let k = rng.below(200);
                    match rng.below(3) {
                        0 => {
                            m.insert(k, k * 9);
                        }
                        1 => {
                            m.erase(k);
                        }
                        _ => {
                            if let Some(v) = m.get(k) {
                                assert_eq!(v, k * 9);
                            }
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn for_each_reports_stashed_original_keys() {
        let m = small();
        let mut oracle = BTreeMap::new();
        for k in 0..800u64 {
            m.insert(k * 3, k + 1);
            oracle.insert(k * 3, k + 1);
        }
        for k in (0..800u64).step_by(2) {
            m.erase(k * 3);
            oracle.remove(&(k * 3));
        }
        let mut got = Vec::new();
        m.for_each(&mut |k, v| got.push((k, v)));
        got.sort_unstable();
        let want: Vec<(u64, u64)> = oracle.into_iter().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn lazy_init_counts_parent_hops() {
        let m = SpoHashMap::with_config(4, 1, 1 << 10, 1 << 14);
        for k in 0..3_000u64 {
            m.insert(k, k);
        }
        assert!(m.stats().init_parent_hops > 0, "lazy init must chase parents");
    }
}
