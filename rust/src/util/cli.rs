//! Minimal command-line parser (clap is unavailable offline).
//!
//! Supports `sub-command --flag --key value --key=value positional` shapes,
//! typed getters with defaults, and a usage dump of everything queried.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| parse_u64_with_suffix(v).unwrap_or_else(|| panic!("--{key}: bad integer '{v}'")))
            .unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.u64_or(key, default as u64) as usize
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad float '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key}: bad bool '{v}'"),
        }
    }

    /// Comma-separated u64 list, e.g. `--threads 4,8,16`.
    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Vec<u64> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    parse_u64_with_suffix(s.trim())
                        .unwrap_or_else(|| panic!("--{key}: bad integer '{s}'"))
                })
                .collect(),
        }
    }
}

/// Parse "123", "10k", "5m", "1b" (decimal suffixes) or "0x.." hex.
pub fn parse_u64_with_suffix(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x") {
        return u64::from_str_radix(hex, 16).ok();
    }
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1_000),
        'm' | 'M' => (&s[..s.len() - 1], 1_000_000),
        'b' | 'B' | 'g' | 'G' => (&s[..s.len() - 1], 1_000_000_000),
        _ => (s, 1),
    };
    num.parse::<u64>().ok().map(|v| v * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        // note: positionals must precede flags (a bare flag would otherwise
        // consume the next token as its value)
        let a = parse(&["exp", "t1", "--threads", "4,8", "--ops=10m", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["t1"]);
        assert_eq!(a.u64_list_or("threads", &[]), vec![4, 8]);
        assert_eq!(a.u64_or("ops", 0), 10_000_000);
        assert!(a.bool_or("verbose", false));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.u64_or("x", 7), 7);
        assert_eq!(a.str_or("s", "d"), "d");
        assert!(!a.bool_or("b", false));
    }

    #[test]
    fn suffixes() {
        assert_eq!(parse_u64_with_suffix("100m"), Some(100_000_000));
        assert_eq!(parse_u64_with_suffix("1b"), Some(1_000_000_000));
        assert_eq!(parse_u64_with_suffix("8k"), Some(8_000));
        assert_eq!(parse_u64_with_suffix("0x10"), Some(16));
        assert_eq!(parse_u64_with_suffix("zzz"), None);
    }

    #[test]
    fn flag_followed_by_flag_is_bool() {
        let a = parse(&["run", "--fast", "--ops", "5"]);
        assert!(a.bool_or("fast", false));
        assert_eq!(a.u64_or("ops", 0), 5);
    }
}
