//! Support utilities: deterministic RNG, statistics, CLI parsing, the mini
//! bench harness and the mini property-testing harness (clap/criterion/
//! proptest are unavailable in the offline build).

pub mod bench;
pub mod cli;
pub mod fail;
pub mod miniprop;
pub mod prefetch;
pub mod rng;
pub mod simd;
pub mod stats;
