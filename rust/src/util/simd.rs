//! Branchless / SIMD intra-leaf search primitives.
//!
//! The fat-leaf skiplist stores up to 32 sorted `u64` keys contiguously per
//! terminal chunk; locating a key inside a chunk is a *rank* computation
//! (how many stored keys are `< target`), which vectorizes as a
//! compare-and-popcount instead of a branchy binary search — on 8–32 sorted
//! keys the branch mispredict cost of bisection exceeds the cost of just
//! comparing everything ("Bridging Cache-Friendliness and Concurrency",
//! PAPERS.md).
//!
//! Three implementations:
//! - a portable scalar fallback that compiles everywhere: a
//!   sum-of-comparisons loop with no data-dependent branches, which LLVM
//!   auto-vectorizes on most targets;
//! - an explicit SSE2 path on `x86_64` (baseline for the architecture, no
//!   runtime feature detection needed): unsigned 64-bit compares via the
//!   sign-bias trick (`x ^ (1 << 63)` maps unsigned order onto signed
//!   order), movemask + popcount;
//! - an AVX2 path selected by runtime `is_x86_feature_detected!` dispatch
//!   (cached in an atomic so the hot path pays one relaxed load): 4 keys
//!   per 256-bit compare with the native `VPCMPGTQ`, same sign-bias trick.
//!
//! All return identical results for all inputs (see the exhaustive
//! cross-check test), so call sites use [`rank`] and never care which ran.

/// Number of keys in `keys` strictly less than `target`.
///
/// For a **sorted** slice this is the partition point: the index where
/// `target` would insert, and the index of `target` itself when present
/// (`keys[rank] == target` iff present). The result is correct for
/// unsorted slices too (it is a pure count), which is what makes the
/// compare-everything formulation legal.
#[inline]
pub fn rank(keys: &[u64], target: u64) -> usize {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: dispatch guard — AVX2 presence was verified at runtime.
            unsafe { rank_avx2(keys, target) }
        } else {
            rank_sse2(keys, target)
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        rank_scalar(keys, target)
    }
}

/// Cached runtime AVX2 probe: 0 = unprobed, 1 = absent, 2 = present.
/// `is_x86_feature_detected!` caches internally too, but routing through
/// one relaxed byte load keeps the hot-path cost explicit and lets tests
/// exercise every code path regardless of the probe outcome.
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static AVX2: AtomicU8 = AtomicU8::new(0);
    match AVX2.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// Portable branchless rank: a comparison is a 0/1 integer, the rank is
/// their sum. No data-dependent branches; auto-vectorizes well.
#[inline]
pub fn rank_scalar(keys: &[u64], target: u64) -> usize {
    let mut r = 0usize;
    for &k in keys {
        r += (k < target) as usize;
    }
    r
}

/// SSE2 rank (x86_64 baseline, always available): 2 keys per 128-bit
/// compare, sign-biased for unsigned order, movemask+popcount to count.
#[cfg(target_arch = "x86_64")]
#[inline]
fn rank_sse2(keys: &[u64], target: u64) -> usize {
    use std::arch::x86_64::*;
    const SIGN: u64 = 1 << 63;
    let mut r = 0usize;
    let mut i = 0usize;
    // SAFETY: SSE2 is part of the x86_64 baseline; loads are unaligned
    // (`loadu`) and bounded by `i + 2 <= keys.len()`.
    unsafe {
        let t = _mm_set1_epi64x((target ^ SIGN) as i64);
        let bias = _mm_set1_epi64x(SIGN as i64);
        while i + 2 <= keys.len() {
            let v = _mm_loadu_si128(keys.as_ptr().add(i) as *const __m128i);
            let biased = _mm_xor_si128(v, bias);
            // key < target  ==  target > key (signed, post-bias)
            let lt = _mm_cmpgt_epi64_fallback(t, biased);
            // each 64-bit lane is all-ones or all-zeros: movemask_pd
            // compresses the two lane sign bits into 2 mask bits
            let mask = _mm_movemask_pd(_mm_castsi128_pd(lt)) as u32;
            r += mask.count_ones() as usize;
            i += 2;
        }
    }
    // odd tail
    if i < keys.len() {
        r += (keys[i] < target) as usize;
    }
    r
}

/// AVX2 rank: 4 keys per 256-bit compare with the native signed 64-bit
/// `VPCMPGTQ` (`_mm256_cmpgt_epi64`), sign-biased for unsigned order,
/// `movemask_pd` compressing the four lane sign bits, popcount to count.
/// The sub-4 tail reuses the scalar formulation.
///
/// # Safety
/// Caller must have verified AVX2 is available (see [`rank`]'s dispatch).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn rank_avx2(keys: &[u64], target: u64) -> usize {
    use std::arch::x86_64::*;
    const SIGN: u64 = 1 << 63;
    let mut r = 0usize;
    let mut i = 0usize;
    let t = _mm256_set1_epi64x((target ^ SIGN) as i64);
    let bias = _mm256_set1_epi64x(SIGN as i64);
    while i + 4 <= keys.len() {
        // SAFETY: unaligned load bounded by `i + 4 <= keys.len()`.
        let v = _mm256_loadu_si256(keys.as_ptr().add(i) as *const __m256i);
        let biased = _mm256_xor_si256(v, bias);
        // key < target  ==  target > key (signed, post-bias)
        let lt = _mm256_cmpgt_epi64(t, biased);
        let mask = _mm256_movemask_pd(_mm256_castsi256_pd(lt)) as u32;
        r += mask.count_ones() as usize;
        i += 4;
    }
    while i < keys.len() {
        r += (keys[i] < target) as usize;
        i += 1;
    }
    r
}

/// Signed 64-bit greater-than compare on SSE2 (no `_mm_cmpgt_epi64` before
/// SSE4.2): compare the halves — `a > b` iff the high signed 32-bit words
/// differ that way, or they are equal and the low unsigned words do.
/// Produces all-ones / all-zeros per 64-bit lane like the native op.
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn _mm_cmpgt_epi64_fallback(
    a: std::arch::x86_64::__m128i,
    b: std::arch::x86_64::__m128i,
) -> std::arch::x86_64::__m128i {
    use std::arch::x86_64::*;
    // high-word signed compare and equality
    let gt32 = _mm_cmpgt_epi32(a, b);
    let eq32 = _mm_cmpeq_epi32(a, b);
    // low-word unsigned compare via sign bias on the 32-bit lanes
    let bias32 = _mm_set1_epi32(i32::MIN);
    let gt_lo_u = _mm_cmpgt_epi32(_mm_xor_si128(a, bias32), _mm_xor_si128(b, bias32));
    // lane = hi_gt | (hi_eq & lo_gt_unsigned), evaluated on the 32-bit
    // grid then broadcast: shuffle each result's high word across its lane
    let hi_gt = _mm_shuffle_epi32(gt32, 0b11_11_01_01);
    let hi_eq = _mm_shuffle_epi32(eq32, 0b11_11_01_01);
    let lo_gt = _mm_shuffle_epi32(gt_lo_u, 0b10_10_00_00);
    _mm_or_si128(hi_gt, _mm_and_si128(hi_eq, lo_gt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rank_naive(keys: &[u64], target: u64) -> usize {
        keys.iter().filter(|&&k| k < target).count()
    }

    #[test]
    fn rank_on_sorted_is_the_partition_point() {
        let keys: Vec<u64> = (0..16).map(|i| i * 10 + 5).collect();
        assert_eq!(rank(&keys, 0), 0);
        assert_eq!(rank(&keys, 5), 0, "equal key does not count");
        assert_eq!(rank(&keys, 6), 1);
        assert_eq!(rank(&keys, 155), 15);
        assert_eq!(rank(&keys, u64::MAX), 16);
        assert_eq!(rank(&[], 7), 0);
    }

    #[test]
    fn rank_matches_naive_on_random_and_adversarial_inputs() {
        let mut rng = Rng::new(99);
        // adversarial values around the sign-bias boundary and extremes
        let spice = [0, 1, (1 << 63) - 1, 1 << 63, (1 << 63) + 1, u64::MAX - 1, u64::MAX];
        for len in 0..=33usize {
            for round in 0..40 {
                let mut keys: Vec<u64> = (0..len)
                    .map(|i| {
                        if round % 3 == 0 && i < spice.len() {
                            spice[i]
                        } else {
                            rng.below(u64::MAX)
                        }
                    })
                    .collect();
                if round % 2 == 0 {
                    keys.sort_unstable();
                }
                for &t in spice.iter().chain(keys.iter()).chain([rng.below(u64::MAX)].iter()) {
                    assert_eq!(
                        rank(&keys, t),
                        rank_naive(&keys, t),
                        "len {len} target {t} keys {keys:?}"
                    );
                    assert_eq!(rank_scalar(&keys, t), rank_naive(&keys, t));
                }
            }
        }
    }

    /// Satellite property: the dispatched, SSE2, AVX2 (when the host has
    /// it), and scalar paths are bit-exact over random blocks, including
    /// the count = 0 (empty) and all-equal-keys edges.
    #[test]
    fn rank_three_paths_are_bit_exact() {
        let mut rng = Rng::new(0x5eed_f00d);
        let spice = [0, 1, (1 << 63) - 1, 1 << 63, (1 << 63) + 1, u64::MAX - 1, u64::MAX];
        let mut check = |keys: &[u64], t: u64| {
            let want = rank_naive(keys, t);
            assert_eq!(rank(keys, t), want, "dispatch: keys {keys:?} target {t}");
            assert_eq!(rank_scalar(keys, t), want, "scalar: keys {keys:?} target {t}");
            #[cfg(target_arch = "x86_64")]
            {
                assert_eq!(rank_sse2(keys, t), want, "sse2: keys {keys:?} target {t}");
                if std::arch::is_x86_feature_detected!("avx2") {
                    // SAFETY: guarded by the runtime feature probe above.
                    assert_eq!(unsafe { rank_avx2(keys, t) }, want, "avx2: keys {keys:?} target {t}");
                }
            }
        };
        // count = 0 edge: every implementation must return 0 on empty input
        for &t in &spice {
            check(&[], t);
        }
        // all-equal-keys edge: rank is 0 or len, nothing in between
        for len in 1..=33usize {
            for &v in &spice {
                let keys = vec![v; len];
                check(&keys, v);
                check(&keys, v.wrapping_add(1));
                check(&keys, v.wrapping_sub(1));
            }
        }
        // random blocks at every length straddling the 2- and 4-lane strides
        for len in 0..=33usize {
            for _ in 0..24 {
                let mut keys: Vec<u64> = (0..len).map(|_| rng.below(u64::MAX)).collect();
                keys.sort_unstable();
                for &t in spice.iter().chain(keys.iter()) {
                    check(&keys, t);
                }
                check(&keys, rng.below(u64::MAX));
            }
        }
    }
}
