//! Small statistics helpers for benchmark reporting (mean/stddev/percentiles),
//! replacing criterion's analysis (criterion is unavailable offline).

/// Summary statistics over a set of f64 samples (e.g. seconds per repetition).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Fixed-bucket latency histogram (power-of-two nanosecond buckets).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>, // bucket i counts samples in [2^i, 2^(i+1)) ns
    count: u64,
    sum_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; 48], count: 0, sum_ns: 0 }
    }

    #[inline]
    pub fn record(&mut self, ns: u64) {
        let b = (64 - ns.max(1).leading_zeros() - 1) as usize;
        self.buckets[b.min(47)] += 1;
        self.count += 1;
        self.sum_ns += ns;
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket holding quantile `q` (bucket-resolution).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 48
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[2.5]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_records_and_quantiles() {
        let mut h = LatencyHistogram::new();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_ns() > 0.0);
        assert!(h.quantile_ns(0.5) <= h.quantile_ns(0.99));
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(100);
        b.record(200);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
