//! Software-prefetch shim for the cache-conscious search paths.
//!
//! "Skiplists with Foresight" (arXiv:1411.1205) shows the dependent-load
//! chain of a skiplist descent is exactly the pattern hardware prefetchers
//! cannot help with: the address of hop `k+1` is only known after hop `k`'s
//! cache miss resolves. Issuing an explicit prefetch for the *next* hop (and
//! the `bottom` child) while the current node is still being examined
//! overlaps the two misses instead of serializing them.
//!
//! One shim, one call site style: `prefetch_read(ptr)` lowers to
//! `prefetcht0` on x86_64 and to a no-op everywhere else (stable Rust has no
//! portable prefetch intrinsic; the fallback keeps the crate buildable on
//! any target). A prefetch is a *hint*: it never faults, even for a wild
//! address, so the function is safe to call with any pointer — callers
//! still bounds-check the slot index so the pointer arithmetic itself stays
//! inside a live block (see `BlockArena::prefetch_hot`).

/// Hint the cache hierarchy to pull the line holding `p` into L1 (T0 hint).
/// Never faults; a no-op on targets without a stable prefetch intrinsic.
#[inline(always)]
pub fn prefetch_read<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(p as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = p;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_is_harmless() {
        // A prefetch must never fault — not for a live pointer, not for
        // null, not for a dangling one (it is only a hint).
        let v = 42u64;
        prefetch_read(&v as *const u64);
        prefetch_read(std::ptr::null::<u64>());
        prefetch_read(usize::MAX as *const u64);
    }
}
