//! Deterministic pseudo-random streams.
//!
//! [`mix64`] is the splitmix64 finalizer applied to `x + GAMMA`; it is
//! **bit-exact** with the L1 Pallas kernel (`python/compile/kernels/hash_mix.py`)
//! and the jnp oracle — the golden vectors below are asserted in all three
//! layers so any drift is caught at test time and at artifact load time.

/// splitmix64 odd gamma.
pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// splitmix64 finalizer of `x + GAMMA` — the repo-wide 64-bit scrambler.
///
/// A bijection on `u64` (no collisions are ever introduced), used as the
/// `boost::hash<uint64_t>` stand-in for H(k) and as the workload key stream
/// (`key[i] = mix64(base + i)`).
#[inline(always)]
pub fn mix64(x: u64) -> u64 {
    let mut x = x.wrapping_add(GAMMA);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Inverse of `x ^ (x >> s)` for `0 < s < 64`: xor the original word back
/// in at every multiple of the shift (`y ^ (y>>s) ^ (y>>2s) ^ ...`).
#[inline(always)]
fn unshift_xor(y: u64, s: u32) -> u64 {
    let mut x = y;
    let mut sh = s;
    while sh < 64 {
        x ^= y >> sh;
        sh += s;
    }
    x
}

/// Exact inverse of [`mix64`] (splitmix64 is a bijection on `u64`):
/// `unmix64(mix64(x)) == x` for every `x`.
///
/// The BST-backed hash tables key their trees by the *scrambled* hash and
/// discard the original key; the ordered-map snapshot fallback uses this
/// inverse to report original keys back out.
#[inline(always)]
pub fn unmix64(h: u64) -> u64 {
    // Modular inverses (mod 2^64) of mix64's two multipliers.
    const INV1: u64 = 0x96DE_1B17_3F11_9089;
    const INV2: u64 = 0x3196_42B2_D24D_8EC3;
    let mut x = unshift_xor(h, 31);
    x = x.wrapping_mul(INV2);
    x = unshift_xor(x, 27);
    x = x.wrapping_mul(INV1);
    x = unshift_xor(x, 30);
    x.wrapping_sub(GAMMA)
}

/// Golden vectors: `mix64(i)` for `i = 0..5`. `mix64(0)` equals the first
/// output of the canonical splitmix64 stream seeded with 0.
pub const GOLDEN: [u64; 5] = [
    0xE220_A839_7B1D_CDAF,
    0x910A_2DEC_8902_5CC1,
    0x9758_35DE_1C97_56CE,
    0x1D0B_14E4_DB01_8FED,
    0x6E73_E372_E233_8ACA,
];

/// Small seedable PRNG (a splitmix64 stream) for tests, workload shuffling
/// and property generation. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = self.state;
        self.state = self.state.wrapping_add(1);
        mix64(s)
    }

    /// Uniform in `[0, bound)` (Lemire-style widening reduction).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    #[inline]
    pub fn chance(&mut self, num: u64, denom: u64) -> bool {
        self.below(denom) < num
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_vectors() {
        for (i, want) in GOLDEN.iter().enumerate() {
            assert_eq!(mix64(i as u64), *want, "mix64({i})");
        }
    }

    #[test]
    fn unmix64_inverts_mix64() {
        for (i, &h) in GOLDEN.iter().enumerate() {
            assert_eq!(unmix64(h), i as u64, "golden {i}");
        }
        for x in 0..1u64 << 16 {
            assert_eq!(unmix64(mix64(x)), x);
        }
        // high/edge values
        for x in [u64::MAX, u64::MAX - 1, 1 << 63, 0xDEAD_BEEF_CAFE_F00D] {
            assert_eq!(unmix64(mix64(x)), x);
            assert_eq!(mix64(unmix64(x)), x, "bijection both ways");
        }
    }

    #[test]
    fn mix64_is_injective_sample() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..1u64 << 16 {
            assert!(seen.insert(mix64(i)));
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
            let v = r.range(5, 9);
            assert!((5..9).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u64> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut ys = xs.clone();
        ys.sort_unstable();
        assert_eq!(ys, (0..100).collect::<Vec<_>>());
    }
}
