//! Deterministic fault injection (`util::fail`).
//!
//! Named failpoint *sites* are compiled into production code paths — the
//! fabric's queue push, owner drain boundaries, completion-slot settle, and
//! arena refill. With the `failpoints` feature **off** (the default) every
//! helper here is an `#[inline(always)]` constant and the sites cost nothing.
//! With the feature **on**, each site consults the installed [`FaultPlan`]:
//! a per-test script that triggers on the Nth hit, on every Nth hit, or by
//! seeded probability, and responds with one of three actions:
//!
//! - **Fail** — the site reports a recoverable error (e.g. a spuriously full
//!   queue, a transiently exhausted arena free list).
//! - **Kill** — the site panics with the typed [`InjectedKill`] payload.
//!   Kill sites are placed only at *op-envelope boundaries* where shard
//!   state is consistent, so a supervisor may catch the unwind, declare the
//!   owner dead, and re-execute pending work idempotently.
//! - **Delay(ns)** — the site sleeps, stretching a race window (slow owner,
//!   delayed completion ack, slow `taken` rendezvous).
//!
//! Plans are installed via a builder and removed by RAII: [`FaultGuard`]
//! holds a global test mutex (chaos tests are serialized — the registry is
//! process-global) and clears the plan on drop, even if the test panicked.
//!
//! Site names currently threaded through the tree:
//!
//! | site                 | seam                                    | actions   |
//! |----------------------|-----------------------------------------|-----------|
//! | `queue.try_push`     | `LfQueue::try_push` entry               | Fail      |
//! | `queue.pop.kill`     | `LfQueue` pop grace period              | Fail      |
//! | `msq.taken.delay`    | `MsQueue` `taken` rendezvous publish    | Delay     |
//! | `fabric.owner.kill`  | owner drain entry / batch boundary      | Kill      |
//! | `fabric.owner.slow`  | owner drain entry                       | Delay     |
//! | `fabric.settle`      | sync completion-slot settle             | Delay     |
//! | `arena.refill`       | magazine refill from shared free list   | Fail      |

/// What a failpoint site should do, decided by the installed plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// No fault — continue on the normal path.
    Proceed,
    /// Report a recoverable, site-specific failure.
    Fail,
    /// Panic with an [`InjectedKill`] payload (caught by the fabric
    /// supervisor, which treats it as a clean owner death).
    Kill,
    /// Sleep for the given number of nanoseconds, then proceed.
    Delay(u64),
}

/// Panic payload carried by an injected owner kill. Supervisors downcast
/// the unwind payload to this type to distinguish a scripted, op-boundary
/// kill (clean: swallow, adopt, re-execute) from a genuine bug (propagate).
#[derive(Clone, Copy, Debug)]
pub struct InjectedKill(pub &'static str);

#[cfg(not(feature = "failpoints"))]
mod imp {
    use super::FaultAction;

    /// Feature off: every site proceeds, for free.
    #[inline(always)]
    pub fn hit(_site: &'static str) -> FaultAction {
        FaultAction::Proceed
    }

    /// Feature off: never fails.
    #[inline(always)]
    pub fn should_fail(_site: &'static str) -> bool {
        false
    }

    /// Feature off: no-op.
    #[inline(always)]
    pub fn point(_site: &'static str) {}

    /// Feature off: no site ever fires.
    #[inline(always)]
    pub fn fires(_site: &'static str) -> u64 {
        0
    }
}

#[cfg(feature = "failpoints")]
mod imp {
    use super::{FaultAction, InjectedKill};
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Mutex, MutexGuard, RwLock};
    use std::time::Duration;

    #[derive(Clone, Copy, Debug)]
    enum Trigger {
        /// Fire exactly once, on the Nth hit (1-based).
        Nth(u64),
        /// Fire on every Nth hit (hits % n == 0).
        EveryNth(u64),
        /// Fire each hit with probability num/den, drawn from the seeded
        /// per-site stream.
        Prob { num: u64, den: u64 },
    }

    #[derive(Clone, Copy, Debug)]
    enum Spec {
        Fail,
        Kill,
        DelayNs(u64),
    }

    #[derive(Clone, Copy, Debug)]
    struct Rule {
        trigger: Trigger,
        spec: Spec,
    }

    struct SiteState {
        rules: Vec<Rule>,
        hits: AtomicU64,
        fired: AtomicU64,
        /// splitmix64 state for Prob triggers; advanced by fetch_add so
        /// concurrent hits draw distinct values. The aggregate fire rate is
        /// seed-deterministic even though the per-thread interleaving isn't.
        rng: AtomicU64,
    }

    /// Fast gate: no plan installed -> one relaxed load per site hit.
    static ACTIVE: AtomicBool = AtomicBool::new(false);
    static REGISTRY: RwLock<Option<HashMap<&'static str, SiteState>>> = RwLock::new(None);
    /// Serializes chaos tests: the registry is process-global, so only one
    /// plan may be live at a time. Poison-tolerant — a panicking chaos test
    /// (injected kills unwind through test code) must not wedge the suite.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

    fn splitmix64(mut z: u64) -> u64 {
        z = z.wrapping_add(GOLDEN);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    fn site_seed(seed: u64, site: &str) -> u64 {
        let mut h = seed ^ 0xCBF2_9CE4_8422_2325;
        for b in site.as_bytes() {
            h = (h ^ *b as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }

    /// Consult the installed plan for `site`. Returns the action the site
    /// should take; does not perform it (see [`should_fail`] / [`point`]).
    pub fn hit(site: &'static str) -> FaultAction {
        if !ACTIVE.load(Ordering::Relaxed) {
            return FaultAction::Proceed;
        }
        let reg = REGISTRY.read().unwrap_or_else(|e| e.into_inner());
        let map = match reg.as_ref() {
            Some(m) => m,
            None => return FaultAction::Proceed,
        };
        let st = match map.get(site) {
            Some(s) => s,
            None => return FaultAction::Proceed,
        };
        let n = st.hits.fetch_add(1, Ordering::Relaxed) + 1;
        for r in &st.rules {
            let fire = match r.trigger {
                Trigger::Nth(k) => n == k,
                Trigger::EveryNth(k) => k > 0 && n % k == 0,
                Trigger::Prob { num, den } => {
                    let draw = splitmix64(st.rng.fetch_add(GOLDEN, Ordering::Relaxed));
                    den > 0 && draw % den < num
                }
            };
            if fire {
                st.fired.fetch_add(1, Ordering::Relaxed);
                return match r.spec {
                    Spec::Fail => FaultAction::Fail,
                    Spec::Kill => FaultAction::Kill,
                    Spec::DelayNs(ns) => FaultAction::Delay(ns),
                };
            }
        }
        FaultAction::Proceed
    }

    /// `true` if the site should report a recoverable failure. Kill and
    /// Delay actions are performed here (panic / sleep) so call sites that
    /// only branch on failure still honor every action kind.
    pub fn should_fail(site: &'static str) -> bool {
        match hit(site) {
            FaultAction::Proceed => false,
            FaultAction::Fail => true,
            FaultAction::Kill => std::panic::panic_any(InjectedKill(site)),
            FaultAction::Delay(ns) => {
                std::thread::sleep(Duration::from_nanos(ns));
                false
            }
        }
    }

    /// Execute the site's action in place: Kill panics, Delay sleeps, Fail
    /// is meaningless at a pure execution point and is ignored.
    pub fn point(site: &'static str) {
        match hit(site) {
            FaultAction::Kill => std::panic::panic_any(InjectedKill(site)),
            FaultAction::Delay(ns) => std::thread::sleep(Duration::from_nanos(ns)),
            FaultAction::Proceed | FaultAction::Fail => {}
        }
    }

    /// How many times `site` has fired (any action) under the current plan.
    pub fn fires(site: &'static str) -> u64 {
        let reg = REGISTRY.read().unwrap_or_else(|e| e.into_inner());
        reg.as_ref()
            .and_then(|m| m.get(site))
            .map(|s| s.fired.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Builder for a per-test fault script. Install with [`FaultPlan::install`].
    pub struct FaultPlan {
        seed: u64,
        rules: HashMap<&'static str, Vec<Rule>>,
    }

    impl FaultPlan {
        pub fn new(seed: u64) -> Self {
            FaultPlan {
                seed,
                rules: HashMap::new(),
            }
        }

        fn push(mut self, site: &'static str, trigger: Trigger, spec: Spec) -> Self {
            self.rules.entry(site).or_default().push(Rule { trigger, spec });
            self
        }

        /// Fail once, on the Nth hit of `site` (1-based).
        pub fn fail_nth(self, site: &'static str, n: u64) -> Self {
            self.push(site, Trigger::Nth(n), Spec::Fail)
        }

        /// Fail on every Nth hit of `site`.
        pub fn fail_every(self, site: &'static str, n: u64) -> Self {
            self.push(site, Trigger::EveryNth(n), Spec::Fail)
        }

        /// Fail each hit with probability `num/den` (seeded stream).
        pub fn fail_prob(self, site: &'static str, num: u64, den: u64) -> Self {
            self.push(site, Trigger::Prob { num, den }, Spec::Fail)
        }

        /// Panic with [`InjectedKill`] on the Nth hit of `site`.
        pub fn kill_nth(self, site: &'static str, n: u64) -> Self {
            self.push(site, Trigger::Nth(n), Spec::Kill)
        }

        /// Sleep `ns` nanoseconds on the Nth hit of `site`.
        pub fn delay_nth(self, site: &'static str, n: u64, ns: u64) -> Self {
            self.push(site, Trigger::Nth(n), Spec::DelayNs(ns))
        }

        /// Sleep `ns` nanoseconds on each hit with probability `num/den`.
        pub fn delay_prob(self, site: &'static str, num: u64, den: u64, ns: u64) -> Self {
            self.push(site, Trigger::Prob { num, den }, Spec::DelayNs(ns))
        }

        /// Install the plan process-wide. The returned guard serializes
        /// chaos tests (global test mutex) and clears the plan on drop.
        pub fn install(self) -> FaultGuard {
            let lock = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
            let mut map = HashMap::new();
            for (site, rules) in self.rules {
                map.insert(
                    site,
                    SiteState {
                        rules,
                        hits: AtomicU64::new(0),
                        fired: AtomicU64::new(0),
                        rng: AtomicU64::new(site_seed(self.seed, site)),
                    },
                );
            }
            {
                let mut reg = REGISTRY.write().unwrap_or_else(|e| e.into_inner());
                *reg = Some(map);
            }
            ACTIVE.store(true, Ordering::SeqCst);
            // Silence the default "thread panicked" report for scripted
            // kills — they are expected control flow under this guard; real
            // panics still reach the previous hook.
            let prev = std::panic::take_hook();
            let prev_for_hook = std::sync::Arc::new(prev);
            let prev_in_hook = prev_for_hook.clone();
            std::panic::set_hook(Box::new(move |info| {
                if info.payload().downcast_ref::<InjectedKill>().is_none() {
                    prev_in_hook(info);
                }
            }));
            FaultGuard {
                _lock: lock,
                prev_hook: Some(prev_for_hook),
            }
        }
    }

    /// RAII handle for an installed [`FaultPlan`]. Dropping it deactivates
    /// all sites, clears the registry, and restores the panic hook.
    pub struct FaultGuard {
        _lock: MutexGuard<'static, ()>,
        prev_hook: Option<std::sync::Arc<Box<dyn Fn(&std::panic::PanicHookInfo<'_>) + Send + Sync>>>,
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            ACTIVE.store(false, Ordering::SeqCst);
            let mut reg = REGISTRY.write().unwrap_or_else(|e| e.into_inner());
            *reg = None;
            drop(reg);
            // Restore the pre-install hook. The Arc is uniquely ours once
            // the installed closure is replaced.
            let _ours = std::panic::take_hook();
            if let Some(prev) = self.prev_hook.take() {
                #[allow(clippy::redundant_closure)]
                std::panic::set_hook(Box::new(move |info| prev(info)));
            }
        }
    }
}

pub use imp::*;

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    #[test]
    fn chaos_nth_trigger_fires_exactly_once() {
        let _g = FaultPlan::new(1).fail_nth("test.site.a", 3).install();
        assert_eq!(hit("test.site.a"), FaultAction::Proceed);
        assert_eq!(hit("test.site.a"), FaultAction::Proceed);
        assert_eq!(hit("test.site.a"), FaultAction::Fail);
        assert_eq!(hit("test.site.a"), FaultAction::Proceed);
        assert_eq!(fires("test.site.a"), 1);
    }

    #[test]
    fn chaos_every_nth_trigger_repeats() {
        let _g = FaultPlan::new(1).fail_every("test.site.b", 2).install();
        let fails = (0..10).filter(|_| should_fail("test.site.b")).count();
        assert_eq!(fails, 5);
        assert_eq!(fires("test.site.b"), 5);
    }

    #[test]
    fn chaos_prob_trigger_rate_is_seeded_and_plausible() {
        let _g = FaultPlan::new(0xC0DE).fail_prob("test.site.c", 1, 4).install();
        let fails = (0..4000).filter(|_| should_fail("test.site.c")).count();
        // 1/4 of 4000 = 1000 expected; allow a generous deterministic band.
        assert!(fails > 700 && fails < 1300, "fails = {fails}");
    }

    #[test]
    fn chaos_unplanned_site_proceeds_and_guard_clears() {
        {
            let _g = FaultPlan::new(1).fail_nth("test.site.d", 1).install();
            assert_eq!(hit("test.site.other"), FaultAction::Proceed);
            assert!(should_fail("test.site.d"));
        }
        // Guard dropped: site is inert again.
        assert_eq!(hit("test.site.d"), FaultAction::Proceed);
        assert_eq!(fires("test.site.d"), 0);
    }

    #[test]
    fn chaos_kill_panics_with_typed_payload() {
        let _g = FaultPlan::new(1).kill_nth("test.site.e", 1).install();
        let r = std::panic::catch_unwind(|| point("test.site.e"));
        let err = r.expect_err("kill site must unwind");
        let k = err
            .downcast_ref::<InjectedKill>()
            .expect("payload must be InjectedKill");
        assert_eq!(k.0, "test.site.e");
    }

    #[test]
    fn chaos_delay_returns_proceedish() {
        let _g = FaultPlan::new(1).delay_nth("test.site.f", 1, 1_000).install();
        // Delay performs the sleep and then reports "no failure".
        assert!(!should_fail("test.site.f"));
        assert_eq!(fires("test.site.f"), 1);
    }
}
