//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` seeded random inputs; on failure it
//! re-runs with shrunk inputs (halved vectors / bisected integers) and
//! reports the smallest failing case plus the seed to reproduce it.

use super::rng::Rng;

/// Outcome of a property over one generated input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random `Vec<u64>` inputs of length `0..=max_len`
/// with values `< max_val`, shrinking on failure. Panics with the minimal
/// counterexample.
pub fn forall_vec_u64<F>(seed: u64, cases: usize, max_len: usize, max_val: u64, mut prop: F)
where
    F: FnMut(&[u64]) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let len = rng.below(max_len as u64 + 1) as usize;
        let input: Vec<u64> = (0..len).map(|_| rng.below(max_val.max(1))).collect();
        if let Err(msg) = prop(&input) {
            let minimal = shrink_vec(&input, &mut prop);
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\n  minimal counterexample ({} elems): {:?}",
                minimal.len(),
                &minimal[..minimal.len().min(64)]
            );
        }
    }
}

/// Run `prop` over `cases` random u64 scalars.
pub fn forall_u64<F>(seed: u64, cases: usize, max_val: u64, mut prop: F)
where
    F: FnMut(u64) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let x = rng.below(max_val.max(1));
        if let Err(msg) = prop(x) {
            let minimal = shrink_u64(x, &mut prop);
            panic!("property failed (seed={seed}, case={case}, input={x}): {msg}\n  minimal counterexample: {minimal}");
        }
    }
}

/// Generic operation for history-based tests on maps/sets/queues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Insert(u64),
    Find(u64),
    Erase(u64),
}

/// Random operation sequences (key universe `[0, key_space)`), with the
/// given percent mix of insert/find/erase.
pub fn gen_ops(rng: &mut Rng, n: usize, key_space: u64, ins_pct: u64, find_pct: u64) -> Vec<Op> {
    (0..n)
        .map(|_| {
            let k = rng.below(key_space.max(1));
            let roll = rng.below(100);
            if roll < ins_pct {
                Op::Insert(k)
            } else if roll < ins_pct + find_pct {
                Op::Find(k)
            } else {
                Op::Erase(k)
            }
        })
        .collect()
}

/// Run `prop` over `cases` random op sequences, shrinking on failure.
pub fn forall_ops<F>(
    seed: u64,
    cases: usize,
    max_len: usize,
    key_space: u64,
    mix: (u64, u64),
    mut prop: F,
) where
    F: FnMut(&[Op]) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let len = rng.below(max_len as u64 + 1) as usize;
        let ops = gen_ops(&mut rng, len, key_space, mix.0, mix.1);
        if let Err(msg) = prop(&ops) {
            let minimal = shrink_ops(&ops, &mut prop);
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\n  minimal counterexample ({} ops): {:?}",
                minimal.len(),
                &minimal[..minimal.len().min(64)]
            );
        }
    }
}

fn shrink_vec<F>(input: &[u64], prop: &mut F) -> Vec<u64>
where
    F: FnMut(&[u64]) -> PropResult,
{
    let mut cur = input.to_vec();
    loop {
        let mut shrunk = false;
        // try removing halves, then quarters
        for chunk in [cur.len() / 2, cur.len() / 4, 1] {
            if chunk == 0 || cur.len() <= 1 {
                continue;
            }
            let mut i = 0;
            while i + chunk <= cur.len() {
                let mut cand = cur.clone();
                cand.drain(i..i + chunk);
                if prop(&cand).is_err() {
                    cur = cand;
                    shrunk = true;
                } else {
                    i += chunk;
                }
            }
        }
        if !shrunk {
            return cur;
        }
    }
}

fn shrink_u64<F>(input: u64, prop: &mut F) -> u64
where
    F: FnMut(u64) -> PropResult,
{
    let mut cur = input;
    while cur > 0 {
        let cand = cur / 2;
        if prop(cand).is_err() {
            cur = cand;
        } else {
            break;
        }
    }
    cur
}

fn shrink_ops<F>(input: &[Op], prop: &mut F) -> Vec<Op>
where
    F: FnMut(&[Op]) -> PropResult,
{
    let mut cur = input.to_vec();
    loop {
        let mut shrunk = false;
        for chunk in [cur.len() / 2, cur.len() / 4, 1] {
            if chunk == 0 || cur.len() <= 1 {
                continue;
            }
            let mut i = 0;
            while i + chunk <= cur.len() {
                let mut cand = cur.clone();
                cand.drain(i..i + chunk);
                if prop(&cand).is_err() {
                    cur = cand;
                    shrunk = true;
                } else {
                    i += chunk;
                }
            }
        }
        if !shrunk {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        forall_vec_u64(1, 50, 100, 1000, |xs| {
            if xs.iter().all(|&x| x < 1000) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        forall_vec_u64(1, 50, 100, 1000, |xs| {
            if xs.contains(&7) {
                Err("contains 7".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_ops_respects_mix() {
        let mut rng = Rng::new(9);
        let ops = gen_ops(&mut rng, 10_000, 100, 100, 0);
        assert!(ops.iter().all(|o| matches!(o, Op::Insert(_))));
    }

    #[test]
    fn scalar_shrink_finds_small() {
        let r = std::panic::catch_unwind(|| {
            forall_u64(2, 100, 1 << 40, |x| {
                if x >= 10 {
                    Err("big".into())
                } else {
                    Ok(())
                }
            })
        });
        assert!(r.is_err());
    }
}
