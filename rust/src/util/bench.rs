//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Runs a closure `reps` times after `warmup` runs, reports a [`Summary`]
//! of wall seconds, and renders paper-style markdown tables.  All paper
//! tables report *seconds for the whole workload averaged over 5 reps* — the
//! same convention is used here.

use std::time::Instant;

use super::stats::Summary;

/// One benchmark measurement: run `f` (whole-workload closure) repeatedly.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// A labelled results table mirroring one paper table: rows keyed by thread
/// count, one column per configuration.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub row_key: String,
    pub columns: Vec<String>,
    pub rows: Vec<(u64, Vec<f64>)>,
}

impl Table {
    pub fn new(title: &str, row_key: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            row_key: row_key.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, key: u64, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((key, values));
    }

    /// Render as github markdown.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&format!("| {} |", self.row_key));
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
        s.push_str("|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (k, vals) in &self.rows {
            s.push_str(&format!("| {k} |"));
            for v in vals {
                s.push_str(&format!(" {v:.6} |"));
            }
            s.push('\n');
        }
        s
    }

    /// Print to stdout (benches tee this into bench_output.txt).
    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }

    /// Render as one JSON object (hand-rolled — serde is unavailable in the
    /// offline build). Schema documented in EXPERIMENTS.md §Bench-artifacts:
    /// `{"title", "row_key", "columns": [...], "rows": [{"key", "values"}]}`.
    /// Non-finite cells (paper columns use NaN for "no datum") become
    /// `null` so the artifact stays valid JSON.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        }
        let cols = self
            .columns
            .iter()
            .map(|c| format!("\"{}\"", esc(c)))
            .collect::<Vec<_>>()
            .join(",");
        let rows = self
            .rows
            .iter()
            .map(|(k, vals)| {
                let vs = vals.iter().map(|&v| num(v)).collect::<Vec<_>>().join(",");
                format!("{{\"key\":{k},\"values\":[{vs}]}}")
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"title\":\"{}\",\"row_key\":\"{}\",\"columns\":[{}],\"rows\":[{}]}}",
            esc(&self.title),
            esc(&self.row_key),
            cols,
            rows
        )
    }
}

/// Standard thread sweep used by every paper table, scaled to the host:
/// the paper sweeps 4..128; `--threads` overrides.
pub fn default_thread_sweep() -> Vec<u64> {
    vec![4, 8, 16, 32, 64, 128]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let mut n = 0;
        let s = measure(1, 3, || n += 1);
        assert_eq!(n, 4);
        assert_eq!(s.n, 3);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("T", "#threads", &["a", "b"]);
        t.push_row(4, vec![1.0, 2.0]);
        let md = t.to_markdown();
        assert!(md.contains("| #threads | a | b |"));
        assert!(md.contains("| 4 | 1.000000 | 2.000000 |"));
    }

    #[test]
    #[should_panic]
    fn table_row_arity_checked() {
        let mut t = Table::new("T", "k", &["a", "b"]);
        t.push_row(1, vec![1.0]);
    }

    #[test]
    fn table_json_shape_and_nan_handling() {
        let mut t = Table::new("Title \"q\"", "#threads", &["a", "paper b"]);
        t.push_row(4, vec![1.5, f64::NAN]);
        t.push_row(8, vec![2.0, 0.25]);
        let j = t.to_json();
        assert!(j.contains("\"title\":\"Title \\\"q\\\"\""), "quotes escaped: {j}");
        assert!(j.contains("\"columns\":[\"a\",\"paper b\"]"));
        assert!(j.contains("{\"key\":4,\"values\":[1.5,null]}"), "NaN -> null: {j}");
        assert!(j.contains("{\"key\":8,\"values\":[2,0.25]}"), "f64 Display: {j}");
        // crude but effective structural sanity: balanced braces/brackets
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}
