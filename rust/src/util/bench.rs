//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Runs a closure `reps` times after `warmup` runs, reports a [`Summary`]
//! of wall seconds, and renders paper-style markdown tables.  All paper
//! tables report *seconds for the whole workload averaged over 5 reps* — the
//! same convention is used here.

use std::time::Instant;

use super::stats::Summary;

/// One benchmark measurement: run `f` (whole-workload closure) repeatedly.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Per-row configuration tag for the trajectory artifact (schema v2 in
/// EXPERIMENTS.md §Bench-artifacts): which execution mode produced the row
/// and under which structure capacities. Empty/zero fields are omitted
/// from the JSON so v1 tables (no tags) emit byte-identical rows.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RowTag {
    /// Execution mode (`"direct"` / `"delegated"` / `"replicated"`); empty
    /// = untagged (single-mode table).
    pub mode: &'static str,
    /// Terminal fat-leaf chunk capacity K (0 = default / not applicable).
    pub leaf_cap: usize,
    /// Fat-inner routing-block capacity F (0 = default / not applicable).
    pub inner_cap: usize,
}

impl RowTag {
    /// Tag carrying only an execution mode.
    pub fn mode(mode: &'static str) -> RowTag {
        RowTag { mode, ..RowTag::default() }
    }

    fn is_empty(&self) -> bool {
        self.mode.is_empty() && self.leaf_cap == 0 && self.inner_cap == 0
    }

    fn to_json_fields(&self) -> String {
        let mut s = String::new();
        if !self.mode.is_empty() {
            s.push_str(&format!(",\"mode\":\"{}\"", self.mode));
        }
        if self.leaf_cap != 0 {
            s.push_str(&format!(",\"leaf_cap\":{}", self.leaf_cap));
        }
        if self.inner_cap != 0 {
            s.push_str(&format!(",\"inner_cap\":{}", self.inner_cap));
        }
        s
    }
}

/// A labelled results table mirroring one paper table: rows keyed by thread
/// count, one column per configuration.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub row_key: String,
    pub columns: Vec<String>,
    pub rows: Vec<(u64, Vec<f64>)>,
    /// Optional per-row tags, parallel to `rows` (padded with empty tags
    /// when plain `push_row` and `push_row_tagged` are mixed).
    pub tags: Vec<RowTag>,
}

impl Table {
    pub fn new(title: &str, row_key: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            row_key: row_key.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            tags: Vec::new(),
        }
    }

    pub fn push_row(&mut self, key: u64, values: Vec<f64>) {
        self.push_row_tagged(key, values, RowTag::default());
    }

    /// `push_row` with a configuration tag emitted into the JSON artifact.
    pub fn push_row_tagged(&mut self, key: u64, values: Vec<f64>, tag: RowTag) {
        assert_eq!(values.len(), self.columns.len());
        self.rows.push((key, values));
        self.tags.push(tag);
    }

    /// Render as github markdown.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&format!("| {} |", self.row_key));
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
        s.push_str("|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (k, vals) in &self.rows {
            s.push_str(&format!("| {k} |"));
            for v in vals {
                s.push_str(&format!(" {v:.6} |"));
            }
            s.push('\n');
        }
        s
    }

    /// Print to stdout (benches tee this into bench_output.txt).
    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }

    /// Render as one JSON object (hand-rolled — serde is unavailable in the
    /// offline build). Schema documented in EXPERIMENTS.md §Bench-artifacts:
    /// `{"title", "row_key", "columns": [...], "rows": [{"key", "values"}]}`.
    /// Non-finite cells (paper columns use NaN for "no datum") become
    /// `null` so the artifact stays valid JSON.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        }
        let cols = self
            .columns
            .iter()
            .map(|c| format!("\"{}\"", esc(c)))
            .collect::<Vec<_>>()
            .join(",");
        let rows = self
            .rows
            .iter()
            .enumerate()
            .map(|(i, (k, vals))| {
                let vs = vals.iter().map(|&v| num(v)).collect::<Vec<_>>().join(",");
                let tag = match self.tags.get(i) {
                    Some(t) if !t.is_empty() => t.to_json_fields(),
                    _ => String::new(),
                };
                format!("{{\"key\":{k},\"values\":[{vs}]{tag}}}")
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"title\":\"{}\",\"row_key\":\"{}\",\"columns\":[{}],\"rows\":[{}]}}",
            esc(&self.title),
            esc(&self.row_key),
            cols,
            rows
        )
    }
}

/// Standard thread sweep used by every paper table, scaled to the host:
/// the paper sweeps 4..128; `--threads` overrides.
pub fn default_thread_sweep() -> Vec<u64> {
    vec![4, 8, 16, 32, 64, 128]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let mut n = 0;
        let s = measure(1, 3, || n += 1);
        assert_eq!(n, 4);
        assert_eq!(s.n, 3);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("T", "#threads", &["a", "b"]);
        t.push_row(4, vec![1.0, 2.0]);
        let md = t.to_markdown();
        assert!(md.contains("| #threads | a | b |"));
        assert!(md.contains("| 4 | 1.000000 | 2.000000 |"));
    }

    #[test]
    #[should_panic]
    fn table_row_arity_checked() {
        let mut t = Table::new("T", "k", &["a", "b"]);
        t.push_row(1, vec![1.0]);
    }

    #[test]
    fn table_json_shape_and_nan_handling() {
        let mut t = Table::new("Title \"q\"", "#threads", &["a", "paper b"]);
        t.push_row(4, vec![1.5, f64::NAN]);
        t.push_row(8, vec![2.0, 0.25]);
        let j = t.to_json();
        assert!(j.contains("\"title\":\"Title \\\"q\\\"\""), "quotes escaped: {j}");
        assert!(j.contains("\"columns\":[\"a\",\"paper b\"]"));
        assert!(j.contains("{\"key\":4,\"values\":[1.5,null]}"), "NaN -> null: {j}");
        assert!(j.contains("{\"key\":8,\"values\":[2,0.25]}"), "f64 Display: {j}");
        // crude but effective structural sanity: balanced braces/brackets
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn table_row_tags_in_json() {
        let mut t = Table::new("T", "k", &["v"]);
        t.push_row(1, vec![1.0]); // untagged rows emit the v1 shape
        t.push_row_tagged(
            2,
            vec![2.0],
            RowTag { mode: "replicated", leaf_cap: 8, inner_cap: 16 },
        );
        t.push_row_tagged(3, vec![3.0], RowTag::mode("direct"));
        let j = t.to_json();
        assert!(j.contains("{\"key\":1,\"values\":[1]}"), "v1 row unchanged: {j}");
        assert!(
            j.contains("{\"key\":2,\"values\":[2],\"mode\":\"replicated\",\"leaf_cap\":8,\"inner_cap\":16}"),
            "full tag: {j}"
        );
        assert!(j.contains("{\"key\":3,\"values\":[3],\"mode\":\"direct\"}"), "mode-only: {j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }
}
