//! # cdskl — Concurrent Deterministic Skiplist and Other Data Structures
//!
//! Reproduction of Sasidharan, *"Concurrent Deterministic Skiplist and Other
//! Data Structures"* (CS.DC 2023) as a three-layer rust + JAX/Pallas stack:
//!
//! - **L3 (rust, this crate)** — the paper's systems: the concurrent
//!   deterministic 1-2-3-4 skiplist ([`skiplist`]), array-block lock-free
//!   queues ([`queue`]), MWMR hash tables ([`hashtable`]), the block memory
//!   manager ([`mem`]), the (virtual) NUMA layer ([`numa`]) and the
//!   hierarchical coordinator ([`coordinator`]).
//! - **L2/L1 (JAX + Pallas, `python/compile/`)** — the batched
//!   keygen/hash/route/histogram data path, AOT-lowered to HLO text and
//!   loaded at startup by [`runtime`] through the PJRT CPU client. Python
//!   never runs on the request path.
//!
//! See DESIGN.md for the system inventory and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod coordinator;
pub mod experiments;
pub mod hashtable;
pub mod mem;
pub mod numa;
pub mod queue;
pub mod runtime;
pub mod skiplist;
pub mod sync;
pub mod util;
pub mod workload;
