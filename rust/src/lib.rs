//! # cdskl — Concurrent Deterministic Skiplist and Other Data Structures
//!
//! Reproduction of Sasidharan, *"Concurrent Deterministic Skiplist and Other
//! Data Structures"* (CS.DC 2023) as a three-layer rust + JAX/Pallas stack:
//!
//! - **L3 (rust, this crate)** — the paper's systems: the concurrent
//!   deterministic 1-2-3-4 skiplist ([`skiplist`]), array-block lock-free
//!   queues ([`queue`]), MWMR hash tables ([`hashtable`]), the block memory
//!   manager ([`mem`]), the (virtual) NUMA layer ([`numa`]) and the
//!   hierarchical coordinator ([`coordinator`]).
//! - **L2/L1 (JAX + Pallas, `python/compile/`)** — the batched
//!   keygen/hash/route/histogram data path, AOT-lowered to HLO text and
//!   loaded at startup by [`runtime`] through the PJRT CPU client (behind
//!   the `aot` cargo feature; the bit-exact native router is the default).
//!   Python never runs on the request path.
//!
//! Every structure speaks the ordered-map API
//! ([`coordinator::OrderedKv`]): `range` scans plus `insert_batch` /
//! `erase_batch`, answered natively off the skiplists' terminal linked
//! list (§IX) and via sorted snapshot by the hash tables. The sharded
//! store fans ranges out per 3-MSB key prefix and concatenates in prefix
//! order — globally sorted by construction, no merge heap (§VI partition).
//!
//! The paper's closing §VI–VII proposal — hierarchical delegation to cut
//! remote-NUMA accesses — runs behind [`coordinator::ExecMode`]: the
//! generic queues carry typed [`coordinator::DelegatedOp`] envelopes over
//! the [`coordinator::OpFabric`] to per-shard owner threads, so in
//! delegated mode no worker ever dereferences remote shard memory
//! (Table XI, `exp t11`).
//!
//! See DESIGN.md for the system inventory and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results and how to run the range
//! workload (`OpMix::RANGE`, `exp t9`).

pub mod coordinator;
pub mod experiments;
pub mod hashtable;
pub mod mem;
pub mod numa;
pub mod queue;
pub mod runtime;
pub mod skiplist;
pub mod sync;
pub mod util;
pub mod workload;
