//! Bench for Table II / figure 4: skiplist workload 1 (10% insert / 90%
//! find), RW-lock baseline vs lock-free find.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table2_skiplist_w1 (paper Table II / fig 4)\n");
    let tables = vec![cdskl::experiments::t2_skiplist_w1(&cfg, &router)];
    common::emit("table2_skiplist_w1", &cfg, &tables);
}
