//! Bench for Table IX (new, beyond the paper): the mixed point/range
//! workload of §IX — skiplist terminal-list scans vs the hash tables'
//! sorted-snapshot fallback, across the sharded store.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table9_range (ordered-map API, paper §IX)\n");
    let tables = vec![cdskl::experiments::t9_range(&cfg, &router)];
    common::emit("table9_range", &cfg, &tables);
}
