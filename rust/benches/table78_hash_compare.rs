//! Bench for Tables VII-VIII / figure 9: tbb-like vs two-level split-order
//! vs two-level BinLists on 100m-class and 1b-class workloads.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(1000);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table78_hash_compare (paper Tables VII-VIII / fig 9)\n");
    let tables = cdskl::experiments::t78_hash_compare(&cfg, &router);
    common::emit("table78_hash_compare", &cfg, &tables);
}
