//! Bench for Table XVI (new, beyond the paper): fat inner nodes —
//! throughput and node derefs/op over routing-block capacity
//! F ∈ {1, 2, 4, 8, 16}, Direct (point `get`) and Delegated
//! (combiner-dispatched scattered probes). Self-asserts a strict deref
//! cut at F ≥ 4 in both modes and BTreeMap-oracle agreement for all
//! eight store kinds at every F.
//!
//! `cargo bench --bench table16_fatinner -- --smoke` runs the CI-sized smoke.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table16_fatinner (fat inner nodes, Table XVI)\n");
    let tables = vec![cdskl::experiments::t16_fatinner(&cfg, &router)];
    common::emit("table16_fatinner", &cfg, &tables);
}
