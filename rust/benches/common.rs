//! Shared bench scaffolding (criterion is unavailable offline; these are
//! plain `harness = false` binaries). Env vars tune the sweep:
//! CDSKL_THREADS="4,8,...", CDSKL_REPS, CDSKL_SCALE (divides paper op
//! counts; default keeps each bench to roughly a minute on one CPU).

use cdskl::experiments::ExpConfig;

pub fn config(default_scale: u64) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    if let Ok(t) = std::env::var("CDSKL_THREADS") {
        cfg.threads = t.split(',').map(|s| s.trim().parse().expect("CDSKL_THREADS")).collect();
    }
    cfg.reps = 1; // keep `cargo bench` to minutes on one CPU
    if let Ok(r) = std::env::var("CDSKL_REPS") {
        cfg.reps = r.parse().expect("CDSKL_REPS");
    }
    cfg.scale = default_scale;
    if let Ok(s) = std::env::var("CDSKL_SCALE") {
        cfg.scale = s.parse().expect("CDSKL_SCALE");
    }
    cfg
}
