//! Shared bench scaffolding (criterion is unavailable offline; these are
//! plain `harness = false` binaries). Env vars tune the sweep:
//! CDSKL_THREADS="4,8,...", CDSKL_REPS, CDSKL_SCALE (divides paper op
//! counts; default keeps each bench to roughly a minute on one CPU).
//! Passing `--smoke` (e.g. `cargo bench --bench table12_cache -- --smoke`)
//! shrinks the run to a CI-sized smoke test.
//!
//! Every bench finishes with [`emit`], which writes a machine-readable
//! `BENCH_<bench>.json` artifact next to the working directory so the perf
//! trajectory is tracked across PRs (schema: EXPERIMENTS.md
//! §Bench-artifacts).

use cdskl::experiments::ExpConfig;
use cdskl::util::bench::Table;

/// `--smoke` anywhere on the bench's argv (cargo forwards args after `--`).
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

pub fn config(default_scale: u64) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    if let Ok(t) = std::env::var("CDSKL_THREADS") {
        cfg.threads = t.split(',').map(|s| s.trim().parse().expect("CDSKL_THREADS")).collect();
    }
    cfg.reps = 1; // keep `cargo bench` to minutes on one CPU
    if let Ok(r) = std::env::var("CDSKL_REPS") {
        cfg.reps = r.parse().expect("CDSKL_REPS");
    }
    cfg.scale = default_scale;
    if let Ok(s) = std::env::var("CDSKL_SCALE") {
        cfg.scale = s.parse().expect("CDSKL_SCALE");
    }
    if smoke() {
        // CI smoke: one tiny rep, two thread points, minimum op counts
        cfg.scale = cfg.scale.max(100_000);
        cfg.threads = vec![2, 4];
        cfg.reps = 1;
    }
    cfg
}

/// Print every table and write the `BENCH_<bench>.json` artifact:
/// `{"bench", "scale", "reps", "threads": [...], "tables": [Table::to_json]}`.
pub fn emit(bench: &str, cfg: &ExpConfig, tables: &[Table]) {
    for t in tables {
        t.print();
    }
    let tjson = tables.iter().map(|t| t.to_json()).collect::<Vec<_>>().join(",");
    let threads =
        cfg.threads.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
    let json = format!(
        "{{\"bench\":\"{bench}\",\"scale\":{},\"reps\":{},\"threads\":[{threads}],\"tables\":[{tjson}]}}\n",
        cfg.scale, cfg.reps
    );
    let path = format!("BENCH_{bench}.json");
    match std::fs::write(&path, json) {
        Ok(()) => println!("(bench artifact written to {path})"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
