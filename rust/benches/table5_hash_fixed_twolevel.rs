//! Bench for Table V / figure 7: fixed-slot vs two-level hash tables on
//! 10m-class and 100m-class 50/50 insert+find workloads.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(200);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table5_hash_fixed_twolevel (paper Table V / fig 7)\n");
    let tables = vec![cdskl::experiments::t5_hash_fixed_twolevel(&cfg, &router)];
    common::emit("table5_hash_fixed_twolevel", &cfg, &tables);
}
