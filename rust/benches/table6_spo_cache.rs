//! Bench for Table VI / figure 8: cache behaviour of one-level vs
//! two-level split-order tables (wall time + cache-miss proxy per op).
mod common;
fn main() {
    let cfg = common::config(100);
    println!("# bench table6_spo_cache (paper Table VI / fig 8)\n");
    let t = cdskl::experiments::t6_spo_cache(&cfg);
    let worst = t
        .rows
        .iter()
        .map(|(_, r)| r[2] / r[3].max(1e-9))
        .fold(0.0f64, f64::max);
    let tables = vec![t];
    common::emit("table6_spo_cache", &cfg, &tables);
    println!("shape: flat/two-level miss-proxy ratio up to {worst:.1}x (paper: up to ~17x wall)");
}
