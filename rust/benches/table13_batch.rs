//! Bench for Table XIII (new, beyond the paper): fused sorted-batch
//! descents + owner-side operation combining — per-key vs fused derefs/op
//! over batch size × clustering, Direct and Delegated. Self-asserts a
//! strict deref cut at batch ≥ 16 in both modes and ≥ 2 caller batches
//! merged per combining drain.
//!
//! `cargo bench --bench table13_batch -- --smoke` runs the CI-sized smoke.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table13_batch (fused sorted-batch descents, Table XIII)\n");
    let tables = vec![cdskl::experiments::t13_batch(&cfg, &router)];
    common::emit("table13_batch", &cfg, &tables);
}
