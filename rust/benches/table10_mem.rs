//! Bench for Table X (new, paper §V): unified-arena churn — footprint vs
//! the eq. (5) prediction, recycle rate, and the per-thread magazine
//! ablation across every arena-backed structure.
mod common;
fn main() {
    let cfg = common::config(100);
    println!("# bench table10_mem (unified mem layer, paper §V)\n");
    let tables = cdskl::experiments::t10_mem(&cfg);
    common::emit("table10_mem", &cfg, &tables);
}
