//! Bench for Table XIV (new, beyond the paper): memory-level-parallel
//! interleaved descents for scattered point batches — throughput and
//! stalled derefs/op over interleave width, Direct (`get_many`) and
//! Delegated (combiner-dispatched `apply_interleaved`). Self-asserts a
//! strict stalled-deref cut at width ≥ 8 in both modes, strictly higher
//! throughput in optimized full-size runs, and that the mixed
//! clustered+scattered window exercises both combiner dispatch arms.
//!
//! `cargo bench --bench table14_mlp -- --smoke` runs the CI-sized smoke.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table14_mlp (MLP interleaved descents, Table XIV)\n");
    let tables = vec![cdskl::experiments::t14_mlp(&cfg, &router)];
    common::emit("table14_mlp", &cfg, &tables);
}
