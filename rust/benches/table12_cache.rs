//! Bench for Table XII (new, beyond the paper): the cache-conscious search
//! path — hot/cold node split + descent prefetching + per-thread search
//! fingers — baseline vs finger-accelerated derefs/op under the
//! repeated-nearby-key workload, Direct and Delegated. Self-asserts hit
//! rate > 50% and a strict deref reduction in both modes.
//!
//! `cargo bench --bench table12_cache -- --smoke` runs the CI-sized smoke.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table12_cache (cache-conscious search path, Table XII)\n");
    let tables = vec![cdskl::experiments::t12_cache(&cfg, &router)];
    common::emit("table12_cache", &cfg, &tables);
}
