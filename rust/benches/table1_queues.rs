//! Bench for Table I / figure 3: queue throughput, tbb-like vs lkfree,
//! 100m-class and 1b-class workloads. `CDSKL_SCALE` tunes size.
mod common;
fn main() {
    let cfg = common::config(1000);
    println!("# bench table1_queues (paper Table I / fig 3)\n");
    let tables = cdskl::experiments::t1_queues(&cfg);
    common::emit("table1_queues", &cfg, &tables);
}
