//! Bench for Table XVIII (new, beyond the paper): NUMA-replicated index
//! layers — Direct vs Delegated vs Replicated drains over read/write
//! mixes 95/5, 70/30 and 50/50, reporting drain seconds, throughput and
//! derefs+hops/op per mode (rows tagged with their execution mode in the
//! JSON artifact). Self-asserts zero remote index-plane derefs for
//! replicated reads, a strict derefs+hops win over Delegated at 95/5,
//! and 8/8 store-kind agreement between Direct and Replicated drains.
//!
//! `cargo bench --bench table18_replica -- --smoke` runs the CI-sized smoke.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table18_replica (replicated index layers, Table XVIII)\n");
    let tables = vec![cdskl::experiments::t18_replica(&cfg, &router)];
    common::emit("table18_replica", &cfg, &tables);
}
