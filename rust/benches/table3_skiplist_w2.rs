//! Bench for Table III / figure 5: skiplist 100m-class, workloads IF and
//! IFE (0.2% erases), RWL vs lock-free find.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(400);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table3_skiplist_w2 (paper Table III / fig 5)\n");
    let tables = vec![cdskl::experiments::t3_skiplist_w2(&cfg, &router)];
    common::emit("table3_skiplist_w2", &cfg, &tables);
}
