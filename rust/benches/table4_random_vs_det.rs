//! Bench for Table IV / figure 6: deterministic 1-2-3-4 skiplist vs the
//! lock-free randomized skiplist. Shape expectation: random wins, by a
//! factor growing with threads.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(400);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table4_random_vs_det (paper Table IV / fig 6)\n");
    let t = cdskl::experiments::t4_random_vs_det(&cfg, &router);
    // shape check: randomized skiplist must win overall
    let (mut det, mut rnd) = (0.0, 0.0);
    for (_, row) in &t.rows {
        det += row[0];
        rnd += row[1];
    }
    let tables = vec![t];
    common::emit("table4_random_vs_det", &cfg, &tables);
    println!("shape: random/deterministic speedup = {:.2}x (paper: 3-12x)", det / rnd);
}
