//! Bench for Table XV (new, beyond the paper): fat-leaf terminal chunks —
//! throughput and node derefs/op over leaf capacity K ∈ {1, 8, 16, 32},
//! Direct (point `get`) and Delegated (combiner-dispatched scattered
//! probes). Self-asserts a strict deref cut at K ≥ 8 in both modes and
//! BTreeMap-oracle agreement for all eight store kinds at every K.
//!
//! `cargo bench --bench table15_fatleaf -- --smoke` runs the CI-sized smoke.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table15_fatleaf (fat-leaf chunks, Table XV)\n");
    let tables = vec![cdskl::experiments::t15_fatleaf(&cfg, &router)];
    common::emit("table15_fatleaf", &cfg, &tables);
}
