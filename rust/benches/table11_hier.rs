//! Bench for Table XI (new, beyond the paper): the §VI–VII hierarchical
//! delegation engine vs direct execution across every store kind, with the
//! locality assertion (`remote_accesses == 0` when delegated) checked on
//! every run.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table11_hier (delegation engine, paper §VI-VII)\n");
    let tables = vec![cdskl::experiments::t11_hier(&cfg, &router)];
    common::emit("table11_hier", &cfg, &tables);
}
