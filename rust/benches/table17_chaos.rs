//! Bench for Table XVII (new, beyond the paper): delegation-fabric chaos —
//! throughput and recovery latency under injected owner kill, slow owner,
//! and queue-full storms. Self-asserts quiescence balance, oracle
//! agreement with an unfaulted Direct run, and (with `--features
//! failpoints`) a recorded owner death with nonzero recovery latency.
//!
//! `cargo bench --bench table17_chaos --features failpoints -- --smoke`
//! runs the CI-sized smoke; without the feature the fault rows degenerate
//! to the baseline.
mod common;
use cdskl::runtime::KeyRouter;
fn main() {
    let cfg = common::config(100);
    let router = KeyRouter::auto("artifacts");
    println!("# bench table17_chaos (fabric fault injection, Table XVII)\n");
    let tables = vec![cdskl::experiments::t17_chaos(&cfg, &router)];
    common::emit("table17_chaos", &cfg, &tables);
}
