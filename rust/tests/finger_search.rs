//! Finger-validity property tests (run in CI as the release cache-path
//! stress step: `CDSKL_SCALE=... cargo test --release -q finger_`).
//!
//! The per-thread search fingers are *hints*: a finger-accelerated
//! `get`/`insert`/`erase` must agree exactly with what a fresh full
//! top-down descent would return, under every store kind and under
//! concurrent insert/erase churn. Generation + key-bounds validation is
//! what makes a stale finger safe (DESIGN.md §Cache-conscious-search);
//! these tests are the executable form of that claim.

use std::collections::BTreeMap;
use std::sync::Arc;

use cdskl::coordinator::{run_with_opts, ExecMode, OrderedKv, RunOptions, ShardedStore, StoreKind};
use cdskl::numa::Topology;
use cdskl::runtime::KeyRouter;
use cdskl::skiplist::{DetSkiplist, FindMode};
use cdskl::util::rng::Rng;
use cdskl::workload::{OpMix, WorkloadSpec};

const ALL_KINDS: [StoreKind; 8] = [
    StoreKind::DetSkiplistLf,
    StoreKind::DetSkiplistRwl,
    StoreKind::RandomSkiplist,
    StoreKind::HashFixed,
    StoreKind::HashTwoLevel,
    StoreKind::HashSpo,
    StoreKind::HashTwoLevelSpo,
    StoreKind::HashTbbLike,
];

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness
/// (CI runs release with CDSKL_SCALE=10 for a deeper soak).
fn scaled_ops(base: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (base / scale.max(1)).max(2_000)
}

/// Nearby-key generator: ops cluster in a moving window — exactly the
/// access pattern that keeps fingers hot (and therefore exercised).
fn nearby_key(rng: &mut Rng, i: u64) -> u64 {
    let window = (i / 64) % 50;
    window * 40 + rng.below(48)
}

/// Every store kind: finger-accelerated ops agree with a BTreeMap oracle
/// op-by-op, and the final state re-verifies with the finger cache
/// disabled (i.e. against fresh full-descent results).
#[test]
fn finger_matches_oracle_on_all_kinds() {
    let ops = scaled_ops(200_000);
    for kind in ALL_KINDS {
        let s = kind.build(1 << 14);
        let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
        let mut rng = Rng::new(0xF1A6 ^ kind as u64);
        for i in 0..ops {
            let k = nearby_key(&mut rng, i);
            match rng.below(10) {
                0..=3 => {
                    let fresh = !oracle.contains_key(&k);
                    assert_eq!(s.insert(k, k ^ 7), fresh, "{kind:?}: insert {k} at op {i}");
                    oracle.entry(k).or_insert(k ^ 7);
                }
                4..=5 => {
                    assert_eq!(s.erase(k), oracle.remove(&k).is_some(), "{kind:?}: erase {k} at op {i}");
                }
                _ => {
                    assert_eq!(s.get(k), oracle.get(&k).copied(), "{kind:?}: get {k} at op {i}");
                }
            }
        }
        assert_eq!(s.len() as usize, oracle.len(), "{kind:?}");
        // finger-accelerated reads agree with the oracle...
        for (&k, &v) in &oracle {
            assert_eq!(s.get(k), Some(v), "{kind:?}: finger get {k}");
        }
        // ...and so do fresh full descents with the cache disabled
        s.set_finger_cache(false);
        for (&k, &v) in &oracle {
            assert_eq!(s.get(k), Some(v), "{kind:?}: full-descent get {k}");
        }
        s.set_finger_cache(true);
    }
}

/// Concurrent churn: writer threads hammer region A with nearby-key
/// insert/erase cycles (keeping their fingers hot and frequently stale as
/// segments split/merge) while reader threads assert region-B keys — never
/// touched by the churners — are always found. Afterwards the structure
/// passes the full invariant check and the fingers demonstrably fired.
#[test]
fn finger_concurrent_churn_never_loses_stable_keys() {
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        let s = Arc::new(DetSkiplist::with_capacity(mode, 1 << 16));
        // region B: stable keys high above the churn region
        let stable_base = 1u64 << 30;
        for i in 0..1_000u64 {
            assert!(s.insert(stable_base + i * 3, i));
        }
        let per = scaled_ops(120_000);
        let mut handles = Vec::new();
        for t in 0..3u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xC0DE + t);
                for i in 0..per {
                    let k = nearby_key(&mut rng, i.wrapping_add(t * 17));
                    if rng.chance(1, 2) {
                        s.insert(k, k ^ 7);
                    } else {
                        s.erase(k);
                    }
                }
            }));
        }
        for _ in 0..2 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xBEEF);
                for i in 0..per {
                    // nearby reads inside the stable region: finger-hot
                    let k = stable_base + ((i % 200) + rng.below(30)) % 1_000 * 3;
                    let idx = (k - stable_base) / 3;
                    assert_eq!(s.get(k), Some(idx), "stable key {k} lost");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let st = s.stats();
        assert!(st.finger_attempts > 0, "{mode:?}: fingers must be consulted");
        assert!(st.finger_hits > 0, "{mode:?}: nearby churn must produce hits");
        let keys = s.check_invariants().unwrap();
        // every stable key still present exactly once (sorted => count them)
        let stable = keys.iter().filter(|&&k| k >= stable_base).count();
        assert_eq!(stable, 1_000, "{mode:?}");
    }
}

/// The engine end-to-end: the hot-window workload through both execution
/// modes on the finger-enabled det store conserves every op and reaches
/// the same deterministic end state as the finger-disabled baseline.
#[test]
fn finger_engine_modes_agree_with_baseline() {
    let ops = scaled_ops(160_000);
    let run = |mode: ExecMode, fingers: bool| {
        let store = Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            4,
            1 << 16,
            Topology::virtual_grid(2, 2),
            4,
        ));
        store.set_finger_cache(fingers);
        // W1 (insert/find only): the resident set is order-independent, so
        // the baseline-vs-fingers equality below is deterministic even when
        // same-key ops land on different worker threads
        let spec = WorkloadSpec::new("fingers", ops, OpMix::W1, 2048).with_hot_span(64, 1024);
        // per-envelope delegated execution: owner-side combining routes
        // pooled ops through the fused run path, which (by design) never
        // consults the fingers this test measures
        let m = run_with_opts(
            &store,
            &spec,
            4,
            &KeyRouter::Native,
            77,
            RunOptions { mode, combining: false, ..RunOptions::default() },
        );
        let st = store.stats();
        (m, st, store)
    };
    for mode in [ExecMode::Direct, ExecMode::Delegated] {
        let (mb, sb, _) = run(mode, false);
        let (mf, sf, store) = run(mode, true);
        assert_eq!(mb.ops(), ops, "{mode:?}: baseline conserves ops");
        assert_eq!(mf.ops(), ops, "{mode:?}: finger run conserves ops");
        assert_eq!(sb.finger_attempts, 0, "{mode:?}: baseline consults no fingers");
        assert!(sf.finger_attempts > 0, "{mode:?}: fingers consulted");
        assert!(sf.finger_hits > 0, "{mode:?}: repeated nearby keys must hit");
        // same seed + spec => same op stream => identical resident set
        assert_eq!(mb.final_len, mf.final_len, "{mode:?}: fingers must not change results");
        assert_eq!(store.len(), mf.final_len, "{mode:?}");
        // and every resident key is readable through the fingers
        let rows = store.range(0, u64::MAX - 2);
        assert_eq!(rows.len() as u64, store.len(), "{mode:?}");
    }
}

/// Deref accounting sanity: under the nearby workload, finger-accelerated
/// descents must touch strictly fewer hot lines per op than full descents
/// on the same single-threaded op sequence (the Table XII claim, here as a
/// deterministic unit-scale check).
#[test]
fn finger_cuts_node_derefs_on_nearby_workload() {
    let run = |fingers: bool| {
        let s = DetSkiplist::with_capacity(FindMode::LockFree, 1 << 14);
        s.set_finger_cache(fingers);
        for k in 0..2_000u64 {
            s.insert(k, k);
        }
        let warm = s.stats();
        let mut rng = Rng::new(3);
        for i in 0..scaled_ops(80_000) {
            let k = nearby_key(&mut rng, i);
            let _ = s.get(k);
        }
        let st = s.stats();
        let attempts = st.finger_attempts - warm.finger_attempts;
        let hits = st.finger_hits - warm.finger_hits;
        let rate = if attempts == 0 { 0.0 } else { hits as f64 / attempts as f64 };
        (st.node_derefs - warm.node_derefs, rate)
    };
    let (base, _) = run(false);
    let (fing, hit_rate) = run(true);
    assert!(
        fing < base,
        "fingers must strictly cut derefs: finger {fing} vs baseline {base}"
    );
    assert!(hit_rate > 0.5, "nearby gets must mostly hit ({hit_rate:.2})");
}
