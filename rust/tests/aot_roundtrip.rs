//! Integration: the AOT path end to end — HLO artifacts load through PJRT,
//! execute, and agree bit-exactly with the native mixer.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not been
//! built; run `make artifacts` first.

use cdskl::runtime::{native_route, KeyRouter, RouteEngine};

fn engine() -> Option<RouteEngine> {
    match RouteEngine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping AOT test: {err:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn artifacts_load_and_selfcheck() {
    let Some(e) = engine() else { return };
    assert!(!e.batch_sizes().is_empty());
    e.self_check().expect("self-check");
}

#[test]
fn aot_route_matches_native_exactly() {
    let Some(e) = engine() else { return };
    for (base, m, n) in [(0u64, 8192u64, 100usize), (999, 1024, 5000), (u64::MAX - 5, 2, 4096)] {
        let got = e.route(base, m, n).expect("route");
        let want = native_route(base, m, n);
        assert_eq!(got.keys, want.keys, "keys base={base} m={m} n={n}");
        assert_eq!(got.hashes, want.hashes);
        assert_eq!(got.shards, want.shards);
        assert_eq!(got.slots, want.slots);
    }
}

#[test]
fn aot_route_chunks_and_pads_tails() {
    let Some(e) = engine() else { return };
    // sizes that exercise: exact small batch, multiple large batches,
    // odd tails shorter than the smallest variant
    let sizes = [1usize, 7, 4096, 4097, 65536, 65536 + 4096 + 3];
    for n in sizes {
        let got = e.route(42, 8192, n).expect("route");
        assert_eq!(got.len(), n, "n={n}");
        let want = native_route(42, 8192, n);
        assert_eq!(got.keys, want.keys, "n={n}");
    }
}

#[test]
fn router_auto_prefers_aot() {
    if engine().is_none() {
        return;
    }
    let r = KeyRouter::auto("artifacts");
    assert!(r.is_aot());
    let b = r.route(3, 64, 10);
    assert_eq!(b.keys, native_route(3, 64, 10).keys);
}

#[test]
fn dispatch_count_amortizes_large_batches() {
    let Some(e) = engine() else { return };
    e.dispatches.set(0);
    let _ = e.route(0, 8192, 65536 * 2).expect("route");
    // 2 dispatches of the 64k variant, not 32 of the 4k one
    assert_eq!(e.dispatches.get(), 2);
}
