//! Churn property tests for the unified §V mem layer (run in CI as the
//! release churn-stress step: `CDSKL_SCALE=... cargo test --release -q
//! mem_churn`).
//!
//! Covers the three latent-bug regressions fixed by the unification:
//! - mass-erase phases larger than the free list no longer deadlock
//!   (`retire` used a blocking push into a fixed 4096x64-slot queue);
//! - the randomized skiplist's recycled allocs are counted (its old inline
//!   arena skipped recycle accounting entirely);
//! - retired nodes are never lost: `retired == recycled + free_residue +
//!   overflow` holds for every structure at quiescence.

use std::sync::atomic::Ordering;

use cdskl::coordinator::{OrderedKv, StoreKind};
use cdskl::experiments::mem::eq5_nodes_prediction;
use cdskl::mem::PoolStats;
use cdskl::skiplist::node::{NodeArena, SENTINEL};
use cdskl::skiplist::{DetSkiplist, FindMode, RandomSkiplist};
use cdskl::util::rng::Rng;

const ALL_KINDS: [StoreKind; 8] = [
    StoreKind::DetSkiplistLf,
    StoreKind::DetSkiplistRwl,
    StoreKind::RandomSkiplist,
    StoreKind::HashFixed,
    StoreKind::HashTwoLevel,
    StoreKind::HashSpo,
    StoreKind::HashTwoLevelSpo,
    StoreKind::HashTbbLike,
];

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness
/// (release CI runs with a small scale => more ops).
fn scaled_ops(paper_ops: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (paper_ops / scale.max(1)).clamp(20_000, 2_000_000)
}

fn assert_no_lost_nodes(kind: &str, st: &PoolStats) {
    assert_eq!(
        st.retired,
        st.recycled + st.free_residue + st.overflow,
        "{kind}: retired nodes must be recycled, parked, or counted as overflow \
         (retired={} recycled={} residue={} overflow={})",
        st.retired,
        st.recycled,
        st.free_residue,
        st.overflow
    );
}

/// Satellite: cross-structure churn over all 8 StoreKinds — alternating
/// insert/erase cycles must keep the arena footprint within 2x of the §V
/// eq. 5 prediction and lose zero nodes.
#[test]
fn mem_churn_all_kinds_bounded_footprint_and_no_lost_nodes() {
    let ops = scaled_ops(2_000_000);
    for (i, kind) in ALL_KINDS.into_iter().enumerate() {
        let s: Box<dyn OrderedKv> = kind.build(1 << 14);
        let mut rng = Rng::new(0xC0FFEE + i as u64);
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..ops {
            if live.is_empty() || rng.chance(1, 2) {
                let k = rng.below(1 << 13);
                if s.insert(k, k + 1) {
                    live.push(k);
                }
            } else {
                let at = rng.below(live.len() as u64) as usize;
                let k = live.swap_remove(at);
                assert!(s.erase(k), "{kind:?}: live key {k} must erase");
            }
        }
        assert_eq!(s.len() as usize, live.len(), "{kind:?}: resident count");
        let st = s.mem_stats();
        if st.capacity == 0 {
            continue; // not arena-backed (BST / chained tables)
        }
        assert_no_lost_nodes(&format!("{kind:?}"), &st);
        assert!(st.recycled > 0, "{kind:?}: churn must recycle");
        let pred = eq5_nodes_prediction(&st);
        assert!(
            (st.capacity as f64) <= 2.0 * pred,
            "{kind:?}: footprint {} nodes exceeds 2x the eq.5 prediction {pred:.0}",
            st.capacity
        );
    }
}

/// Satellite regression: a mass-erase phase bigger than the OLD free-queue
/// capacity (a fixed 4096-slot x 64-block queue = 262,144 entries,
/// regardless of arena size) used to spin forever inside `retire` because
/// the queue was built with `block_on_full=true`. The unified arena sizes
/// the free list to pool capacity and never blocks; this test simply has
/// to terminate, absorb every retire, and keep serving allocs.
#[test]
fn mem_churn_mass_erase_exceeding_old_free_queue_capacity() {
    const N: u64 = 300_000; // > 262,144
    let a = NodeArena::new(8192, 40); // capacity 327,680 nodes
    let refs: Vec<u64> = (0..N).map(|k| a.alloc(k, SENTINEL, SENTINEL, 0, 0)).collect();
    for r in &refs {
        a.node(*r).cold.mark.store(true, Ordering::Release);
        a.retire(*r);
    }
    let st = a.stats();
    assert_eq!(st.retired, N);
    assert_no_lost_nodes("NodeArena", &st);
    assert_eq!(st.overflow, 0, "a capacity-sized free list must absorb a full mass erase");
    // the arena still serves allocations, from the recycled set
    let cap = a.capacity();
    for k in 0..10_000u64 {
        let _ = a.alloc(k, SENTINEL, SENTINEL, 0, 0);
    }
    assert_eq!(a.capacity(), cap, "post-erase allocs must reuse retired slots");
}

/// Satellite: recycle/retire accounting parity between the randomized
/// skiplist (whose old inline arena never counted recycles) and the
/// deterministic skiplist's NodeArena — both now report through the same
/// unified counters and satisfy the same invariants.
#[test]
fn mem_churn_recycle_accounting_parity_random_vs_det() {
    let det = DetSkiplist::with_capacity(FindMode::LockFree, 1 << 14);
    let rnd = RandomSkiplist::with_capacity(1 << 14);
    let cycles = scaled_ops(400_000);
    for k in 0..cycles {
        let key = k % 257;
        assert_eq!(det.insert(key, k), rnd.insert(key, k), "insert({key})");
        assert_eq!(det.erase(key), rnd.erase(key), "erase({key})");
    }
    for (name, st) in [("det", det.mem_stats()), ("random", rnd.mem_stats())] {
        assert!(st.allocs >= cycles, "{name}: every insert allocates");
        assert!(st.retired >= cycles, "{name}: every erase retires");
        assert!(
            st.recycled * 2 > st.allocs,
            "{name}: alternating churn must be recycle-dominated (recycled={} allocs={})",
            st.recycled,
            st.allocs
        );
        assert!(st.magazine_hits > 0, "{name}: magazines must serve the churn");
        assert_no_lost_nodes(name, &st);
        assert_eq!(st.blocks, 1, "{name}: alternating churn stays in one block");
    }
}

/// The typed NodePool façade obeys the same invariants under a random
/// alloc/retire history (it shares the BlockArena body).
#[test]
fn mem_churn_nodepool_facade_shares_the_invariants() {
    let pool: cdskl::mem::NodePool<u64> = cdskl::mem::NodePool::new(64, 256);
    let mut rng = Rng::new(77);
    let mut live: Vec<usize> = Vec::new();
    let mut peak = 0usize;
    for _ in 0..scaled_ops(400_000) {
        if live.is_empty() || rng.chance(1, 2) {
            live.push(pool.alloc() as usize);
            peak = peak.max(live.len());
        } else {
            let at = rng.below(live.len() as u64) as usize;
            let p = live.swap_remove(at);
            pool.retire(p as *mut _);
        }
    }
    let st = pool.stats();
    assert_no_lost_nodes("NodePool", &st);
    // §V bound: blocks <= ceil(peak / C) + one block of magazine slack
    // (slots parked in per-thread magazines can defer reuse briefly)
    assert!(
        st.blocks <= (peak as u64).div_ceil(64) + 1,
        "blocks {} exceed the §V bound for peak {peak}",
        st.blocks
    );
}
