//! Hierarchical-delegation correctness tests (run in CI as the release
//! delegation-stress step: `CDSKL_SCALE=... cargo test --release -q hier_`).
//!
//! A `BTreeMap` oracle drives the typed-op fabric end to end for every
//! `StoreKind`: synchronous calls must return exactly what the oracle
//! predicts (insert/erase applied-ness, find values, range rows), async
//! batched delegation must quiesce with every completion aggregated into
//! the caller's padded slot, and — the paper's §VI–VII claim — every
//! delegated shard dereference must land on the shard's home NUMA node
//! (`remote == 0`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cdskl::coordinator::{
    for_each_prefix_segment, DelegatedOp, OpFabric, OpResult, ShardedStore, StoreKind,
};
// The canonical 8-kind list, shared with Table XI so the two can't drift.
use cdskl::experiments::hier::T11_KINDS as ALL_KINDS;
use cdskl::numa::{pin_to_cpu, Topology};
use cdskl::util::rng::Rng;

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness
/// (release CI runs with a small scale => more ops).
fn scaled_ops(paper_ops: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (paper_ops / scale.max(1)).clamp(800, 200_000)
}

/// Key universe: all 8 prefix segments, small per-segment offsets so finds
/// and erases collide with earlier inserts.
fn gen_key(rng: &mut Rng) -> u64 {
    (rng.below(8) << 61) | rng.below(512)
}

/// Run `body(caller_id, fabric, store)` while `threads` pinned owner
/// threads drain the fabric; owners exit once `body` returns and their
/// queues are empty.
fn with_owner_pool<R>(
    kind: StoreKind,
    threads: usize,
    topo: Topology,
    batch_n: usize,
    body: impl FnOnce(usize, &OpFabric, &ShardedStore) -> R,
) -> (R, Arc<ShardedStore>, Arc<OpFabric>) {
    let store = Arc::new(ShardedStore::new(kind, 8, 1 << 13, topo.clone(), threads));
    let fabric = Arc::new(OpFabric::new(threads, 1, 8, topo, 64, batch_n));
    let stop = Arc::new(AtomicBool::new(false));
    let out = std::thread::scope(|scope| {
        for t in 0..threads {
            let fabric = fabric.clone();
            let store = store.clone();
            let stop = stop.clone();
            scope.spawn(move || {
                pin_to_cpu(t);
                loop {
                    let n = fabric.drain(t, &store, 16);
                    if n == 0 {
                        if stop.load(Ordering::Acquire) && fabric.pending_batches() == 0 {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            });
        }
        let r = body(threads, &fabric, &store);
        stop.store(true, Ordering::Release);
        r
    });
    (out, store, fabric)
}

/// Acceptance: synchronous delegated insert/find/erase/range agree with a
/// sequential BTreeMap oracle on every store kind.
#[test]
fn hier_delegated_matches_btreemap_oracle_all_kinds() {
    let ops = scaled_ops(200_000).min(4_000); // sync round-trips are costly
    for (i, kind) in ALL_KINDS.into_iter().enumerate() {
        let ((), store, fabric) = with_owner_pool(
            kind,
            4,
            Topology::virtual_grid(2, 2),
            8,
            |caller_id, fabric, store| {
                let mut caller = fabric.caller(caller_id, None);
                let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
                let mut rng = Rng::new(0x11E8 + i as u64);
                for n in 0..ops {
                    let k = gen_key(&mut rng);
                    match rng.below(100) {
                        0..=39 => {
                            let v = n ^ 0xABCD;
                            let got = caller
                                .call(DelegatedOp::Insert { key: k, value: v }, store)
                                .unwrap();
                            // set semantics: a duplicate insert keeps the
                            // old value and reports not-applied
                            let fresh = !oracle.contains_key(&k);
                            if fresh {
                                oracle.insert(k, v);
                            }
                            assert_eq!(got, OpResult::Applied(fresh), "{kind:?} insert {k}");
                        }
                        40..=64 => {
                            let got =
                                caller.call(DelegatedOp::Find { key: k }, store).unwrap();
                            assert_eq!(
                                got,
                                OpResult::Value(oracle.get(&k).copied()),
                                "{kind:?} find {k}"
                            );
                        }
                        65..=84 => {
                            let got =
                                caller.call(DelegatedOp::Erase { key: k }, store).unwrap();
                            assert_eq!(
                                got,
                                OpResult::Applied(oracle.remove(&k).is_some()),
                                "{kind:?} erase {k}"
                            );
                        }
                        _ => {
                            // windows sized to cross prefix boundaries now
                            // and then (lo near a segment top)
                            let lo = if rng.below(4) == 0 {
                                (rng.below(7) << 61) | (((1u64 << 61) - 1) - rng.below(64))
                            } else {
                                k
                            };
                            let hi = lo.saturating_add(rng.below(1u64 << 62));
                            let rows = sync_range(&mut caller, lo, hi, store);
                            let want: Vec<(u64, u64)> =
                                oracle.range(lo..=hi).map(|(k, v)| (*k, *v)).collect();
                            assert_eq!(rows, want, "{kind:?} range [{lo:#x}, {hi:#x}]");
                        }
                    }
                }
                caller.finish(store);
            },
        );
        // end state agrees and every dereference was NUMA-local
        let (_, remote) = store.locality.snapshot();
        assert_eq!(remote, 0, "{kind:?}: delegated ops must stay on home nodes");
        assert_eq!(fabric.stats().remote_exec, 0, "{kind:?}: fabric routing invariant");
    }
}

/// Sync cross-shard range: split per prefix (like the async
/// `delegate_range`) and concatenate the per-owner results in prefix
/// order — globally sorted by construction.
fn sync_range(
    caller: &mut cdskl::coordinator::Caller<'_>,
    lo: u64,
    hi: u64,
    store: &ShardedStore,
) -> Vec<(u64, u64)> {
    let mut out = Vec::new();
    for_each_prefix_segment(lo, hi, |slo, shi| {
        match caller.call(DelegatedOp::Range { lo: slo, hi: shi }, store).unwrap() {
            OpResult::Rows(rows) => out.extend(rows),
            other => panic!("range returned {other:?}"),
        }
    });
    out
}

/// Acceptance: async batched delegation (the engine's fast path) quiesces,
/// aggregates completions into the caller's slot, and matches the oracle
/// at quiescence — including `Batch` envelopes and cross-shard ranges.
#[test]
fn hier_async_batched_delegation_quiesces_and_aggregates() {
    let n = scaled_ops(400_000);
    for kind in [StoreKind::DetSkiplistLf, StoreKind::HashTwoLevelSpo] {
        let ((), store, fabric) = with_owner_pool(
            kind,
            4,
            Topology::virtual_grid(2, 2),
            16,
            |caller_id, fabric, store| {
                let mut caller = fabric.caller(caller_id, None);
                let mut rng = Rng::new(0xA57C);
                let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
                // phase 1: a bulk Batch envelope per shard
                let bulk: Vec<(u64, u64)> =
                    (0..256u64).map(|i| ((i % 8) << 61 | i, i + 1)).collect();
                for &(k, v) in &bulk {
                    oracle.insert(k, v);
                }
                caller.delegate_insert_batch(&bulk, store);
                // phase 2: async singles — per-owner FIFO keeps each key's
                // insert ahead of its erase within this caller
                for _ in 0..n {
                    let k = gen_key(&mut rng);
                    if rng.below(3) < 2 {
                        // set semantics: a duplicate insert keeps the old value
                        oracle.entry(k).or_insert(k ^ 7);
                        caller.delegate(DelegatedOp::Insert { key: k, value: k ^ 7 }, store);
                    } else {
                        oracle.remove(&k);
                        caller.delegate(DelegatedOp::Erase { key: k }, store);
                    }
                }
                // phase 3: full-space scans aggregate rows into our slot
                let subs = caller.delegate_range(0, u64::MAX, store);
                assert_eq!(subs, 8);
                caller.finish(store);
                // quiesce: every submitted op executed
                let t0 = std::time::Instant::now();
                while fabric.stats().executed != fabric.stats().submitted {
                    std::thread::yield_now();
                    assert!(t0.elapsed().as_secs() < 120, "{kind:?}: fabric failed to quiesce");
                }
                // resident state matches the oracle exactly
                let got = store.range(0, u64::MAX);
                let want: Vec<(u64, u64)> = oracle.iter().map(|(k, v)| (*k, *v)).collect();
                assert_eq!(got, want, "{kind:?}: end state vs oracle");
            },
        );
        let st = fabric.stats();
        assert_eq!(st.executed, st.submitted, "{kind:?}");
        assert!(st.batch_occupancy() > 1.0, "{kind:?}: flush-on-N must batch");
        assert!(st.queued_batches > 0, "{kind:?}: a slot-only caller always queues");
        let totals = fabric.slot_totals(4);
        assert_eq!(totals.acked, st.executed, "{kind:?}: single caller acks everything");
        assert!(totals.rows > 0, "{kind:?}: scan rows aggregate to the caller");
        let (_, remote) = store.locality.snapshot();
        assert_eq!(remote, 0, "{kind:?}: async path is NUMA-local too");
    }
}

/// Every store kind survives a quick async churn through the fabric with
/// zero remote shard dereferences (the t11 assertion at test scale).
#[test]
fn hier_delegation_is_numa_local_for_every_kind() {
    let n = scaled_ops(100_000);
    for kind in ALL_KINDS {
        let ((), store, fabric) = with_owner_pool(
            kind,
            4,
            Topology::virtual_grid(2, 2),
            16,
            |caller_id, fabric, store| {
                let mut caller = fabric.caller(caller_id, None);
                let mut rng = Rng::new(0x10CA1);
                for _ in 0..n {
                    let k = gen_key(&mut rng);
                    match rng.below(4) {
                        0 => caller.delegate(DelegatedOp::Insert { key: k, value: k }, store),
                        1 => caller.delegate(DelegatedOp::Erase { key: k }, store),
                        2 => {
                            caller.delegate_range(k, k.saturating_add(1 << 61), store);
                        }
                        _ => caller.delegate(DelegatedOp::Find { key: k }, store),
                    }
                }
                caller.finish(store);
                let t0 = std::time::Instant::now();
                while fabric.stats().executed != fabric.stats().submitted {
                    std::thread::yield_now();
                    assert!(t0.elapsed().as_secs() < 120, "{kind:?}: fabric failed to quiesce");
                }
            },
        );
        let (local, remote) = store.locality.snapshot();
        assert_eq!(remote, 0, "{kind:?}: delegated execution must be NUMA-local");
        assert!(local > 0, "{kind:?}");
        assert_eq!(fabric.stats().remote_exec, 0, "{kind:?}");
    }
}
