//! Fat-leaf terminal-chunk correctness tests (run in CI as the release
//! fat-leaf stress step: `CDSKL_SCALE=... cargo test --release -q fatleaf_`).
//!
//! Every swept leaf capacity K must be behaviourally invisible: a
//! `DetSkiplist` at K ∈ {1, 8, 16} on both find modes must track a
//! sequential `BTreeMap` oracle through point churn, fused sorted runs,
//! the interleaved engine and cross-chunk range scans, keep its structural
//! invariants (per-chunk occupancy ∈ [K/4, K], in-chunk sort, 1-2-3-4
//! arity) through split/merge boundary hammering, and survive concurrent
//! mixed churn with a quiescent full validation.

use std::collections::BTreeMap;
use std::sync::Arc;

use cdskl::mem::ArenaOptions;
use cdskl::skiplist::{BatchOp, BatchReply, DetSkiplist, FindMode};
use cdskl::util::rng::Rng;

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness
/// (CI runs release with CDSKL_SCALE=10 for a deeper soak).
fn scaled(n: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (n / scale.max(1)).clamp(500, 200_000)
}

fn new_sl(mode: FindMode, cap: usize) -> DetSkiplist {
    DetSkiplist::with_leaf_cap_on(mode, 1 << 15, ArenaOptions::default(), cap)
}

const CAPS: [usize; 3] = [1, 8, 16];

/// Point insert/get/erase churn against the oracle, with periodic and
/// final structural validation, at every swept K on both find modes.
#[test]
fn fatleaf_point_churn_matches_btreemap_oracle() {
    let ops = scaled(40_000);
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        for cap in CAPS {
            let s = new_sl(mode, cap);
            let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
            let mut rng = Rng::new(0xFA7 + cap as u64);
            for i in 0..ops {
                // tight key space: constant re-insert/erase collisions
                let k = rng.below(ops / 8 + 16) + 1;
                match rng.below(5) {
                    0 | 1 | 2 => {
                        let fresh = !oracle.contains_key(&k);
                        if fresh {
                            oracle.insert(k, k ^ 7);
                        }
                        assert_eq!(s.insert(k, k ^ 7), fresh, "{mode:?} K={cap} insert {k}");
                    }
                    3 => {
                        assert_eq!(
                            s.erase(k),
                            oracle.remove(&k).is_some(),
                            "{mode:?} K={cap} erase {k}"
                        );
                    }
                    _ => {
                        assert_eq!(
                            s.get(k),
                            oracle.get(&k).copied(),
                            "{mode:?} K={cap} get {k}"
                        );
                    }
                }
                if i % 4096 == 0 {
                    s.check_invariants().unwrap_or_else(|e| {
                        panic!("{mode:?} K={cap} invariants broke at op {i}: {e}")
                    });
                }
            }
            assert_eq!(s.len(), oracle.len() as u64, "{mode:?} K={cap}");
            let keys = s.check_invariants().expect("final validation");
            let want: Vec<u64> = oracle.keys().copied().collect();
            assert_eq!(keys, want, "{mode:?} K={cap}: terminal walk vs oracle");
        }
    }
}

/// The fused sorted-run path must produce the same replies and end state
/// as the equivalent per-key loop (a twin list), at every K on both modes
/// — runs mix all three op types with duplicate keys.
#[test]
fn fatleaf_fused_runs_match_point_twin() {
    let rounds = 6;
    let per_round = scaled(12_000).min(4_000) as usize;
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        for cap in CAPS {
            let fused = new_sl(mode, cap);
            let twin = new_sl(mode, cap);
            let mut rng = Rng::new(0xF5ED + cap as u64);
            for round in 0..rounds {
                let mut run: Vec<BatchOp> = (0..per_round)
                    .map(|_| {
                        let k = rng.below(per_round as u64 * 2 + 8) + 1;
                        match rng.below(4) {
                            0 | 1 => BatchOp::Insert(k, k ^ 9),
                            2 => BatchOp::Erase(k),
                            _ => BatchOp::Get(k),
                        }
                    })
                    .collect();
                run.sort_by_key(|op| op.key());
                let mut fused_replies = vec![BatchReply::Applied(false); run.len()];
                fused.apply_sorted_run(&run, &mut |i, r| fused_replies[i] = r);
                for (i, op) in run.iter().enumerate() {
                    let want = match *op {
                        BatchOp::Insert(k, v) => BatchReply::Applied(twin.insert(k, v)),
                        BatchOp::Erase(k) => BatchReply::Applied(twin.erase(k)),
                        BatchOp::Get(k) => BatchReply::Value(twin.get(k)),
                    };
                    assert_eq!(
                        fused_replies[i], want,
                        "{mode:?} K={cap} round {round} op {i} ({op:?})"
                    );
                }
                let fk = fused.check_invariants().expect("fused invariants");
                let tk = twin.check_invariants().expect("twin invariants");
                assert_eq!(fk, tk, "{mode:?} K={cap} round {round}: end states diverged");
            }
        }
    }
}

/// The interleaved engine (scattered-batch MLP path) must agree with the
/// oracle for lookups (`get_many`) and with the fused path for mixed runs
/// (`apply_interleaved`), at every K.
#[test]
fn fatleaf_interleaved_matches_oracle() {
    let n = scaled(20_000);
    for cap in CAPS {
        let s = new_sl(FindMode::LockFree, cap);
        let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
        // scattered resident set (odd stride keeps neighbours far apart)
        for i in 0..n {
            let k = i * 173 + 5;
            assert!(s.insert(k, i));
            oracle.insert(k, i);
        }
        // unsorted scattered probes, half misses, through every width
        let mut rng = Rng::new(0x111 + cap as u64);
        let probes: Vec<u64> = (0..scaled(8_000)).map(|_| rng.below(n * 173 + 10)).collect();
        for width in [1usize, 4, 8] {
            let got = s.get_many(&probes, width);
            for (i, &k) in probes.iter().enumerate() {
                assert_eq!(got[i], oracle.get(&k).copied(), "K={cap} width {width} get {k}");
            }
        }
        // mixed interleaved run vs its oracle effect
        let mut run: Vec<BatchOp> = (0..scaled(4_000))
            .map(|_| {
                let k = rng.below(n * 173 + 10);
                match rng.below(3) {
                    0 => BatchOp::Insert(k, k ^ 1),
                    1 => BatchOp::Erase(k),
                    _ => BatchOp::Get(k),
                }
            })
            .collect();
        run.sort_by_key(|op| op.key());
        s.apply_interleaved(&run, 8, &mut |i, r| {
            let want = match run[i] {
                BatchOp::Insert(k, v) => {
                    let fresh = !oracle.contains_key(&k);
                    if fresh {
                        oracle.insert(k, v);
                    }
                    BatchReply::Applied(fresh)
                }
                BatchOp::Erase(k) => BatchReply::Applied(oracle.remove(&k).is_some()),
                BatchOp::Get(k) => BatchReply::Value(oracle.get(&k).copied()),
            };
            assert_eq!(r, want, "K={cap} interleaved op {i} ({:?})", run[i]);
        });
        assert_eq!(s.len(), oracle.len() as u64, "K={cap}");
        s.check_invariants().expect("post-interleave validation");
    }
}

/// Range scans crossing many chunk boundaries — including ranges starting
/// and ending mid-chunk, empty ranges and full sweeps — vs the oracle.
#[test]
fn fatleaf_ranges_span_chunk_boundaries() {
    let n = scaled(10_000);
    for cap in CAPS {
        let s = new_sl(FindMode::LockFree, cap);
        let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
        let mut rng = Rng::new(0x4A6E + cap as u64);
        for _ in 0..n {
            let k = rng.below(n * 3) + 1;
            if s.insert(k, k * 2) {
                oracle.insert(k, k * 2);
            }
        }
        // punch holes so chunk fills vary across the list
        for _ in 0..n / 3 {
            let k = rng.below(n * 3) + 1;
            if s.erase(k) {
                oracle.remove(&k);
            }
        }
        for _ in 0..200 {
            let lo = rng.below(n * 3);
            let hi = lo + rng.below(cap as u64 * 40 + 64);
            let want: Vec<(u64, u64)> =
                oracle.range(lo..=hi).map(|(&k, &v)| (k, v)).collect();
            assert_eq!(s.range(lo, hi), want, "K={cap} range [{lo}, {hi}]");
        }
        assert!(s.range(5, 4).is_empty(), "inverted bounds are empty");
        let all: Vec<(u64, u64)> = oracle.iter().map(|(&k, &v)| (k, v)).collect();
        assert_eq!(s.range(0, u64::MAX - 2), all, "K={cap} full sweep");
    }
}

/// Boundary hammer: ascending fill (every chunk split fires at exactly
/// K full) then descending erase (merge/borrow fires at exactly K/4),
/// validating the occupancy invariant at tight intervals throughout.
#[test]
fn fatleaf_split_merge_boundary_hammer() {
    let n = scaled(6_000);
    for cap in [8usize, 16, 32] {
        let s = new_sl(FindMode::LockFree, cap);
        for i in 0..n {
            assert!(s.insert(i + 1, i));
            if i % (cap as u64) == cap as u64 - 1 {
                s.check_invariants()
                    .unwrap_or_else(|e| panic!("K={cap} fill at {i}: {e}"));
            }
        }
        // descending erase drains the rightmost chunks first: constant
        // underflow at the moving boundary
        for i in (0..n).rev() {
            assert!(s.erase(i + 1), "K={cap} erase {}", i + 1);
            if i % (cap as u64) == 0 {
                s.check_invariants()
                    .unwrap_or_else(|e| panic!("K={cap} drain at {i}: {e}"));
            }
        }
        assert_eq!(s.len(), 0);
        // striped erase from a fresh fill: merges between interior chunks
        for i in 0..n {
            s.insert(i + 1, i);
        }
        let mut left = n;
        for i in 0..n {
            if i % 4 != 3 {
                assert!(s.erase(i + 1));
                left -= 1;
            }
            if i % 512 == 0 {
                s.check_invariants()
                    .unwrap_or_else(|e| panic!("K={cap} stripe at {i}: {e}"));
            }
        }
        assert_eq!(s.len(), left);
        s.check_invariants().expect("post-stripe validation");
    }
}

/// Concurrent mixed churn at fat-leaf capacities: disjoint per-thread key
/// ranges (every reply assertable) plus a shared contended stripe, on both
/// find modes, with a quiescent full validation at the end.
#[test]
fn fatleaf_concurrent_churn_validates_quiescently() {
    let per_thread = scaled(8_000).min(6_000);
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        for cap in [8usize, 16] {
            let s = Arc::new(DetSkiplist::with_leaf_cap_on(
                mode,
                1 << 16,
                ArenaOptions::default(),
                cap,
            ));
            let threads = 6u64;
            std::thread::scope(|scope| {
                for t in 0..threads {
                    let s = s.clone();
                    scope.spawn(move || {
                        let mut rng = Rng::new(0xC0C0 + t);
                        let base = (t + 1) << 40; // disjoint range per thread
                        let mut mine: BTreeMap<u64, u64> = BTreeMap::new();
                        for i in 0..per_thread {
                            let k = base + rng.below(per_thread / 2 + 8);
                            match rng.below(4) {
                                0 | 1 => {
                                    let fresh = !mine.contains_key(&k);
                                    if fresh {
                                        mine.insert(k, t);
                                    }
                                    assert_eq!(s.insert(k, t), fresh, "t{t} insert {k}");
                                }
                                2 => {
                                    assert_eq!(
                                        s.erase(k),
                                        mine.remove(&k).is_some(),
                                        "t{t} erase {k}"
                                    );
                                }
                                _ => {
                                    assert_eq!(
                                        s.get(k),
                                        mine.get(&k).copied(),
                                        "t{t} get {k}"
                                    );
                                }
                            }
                            // shared stripe: pure contention, no asserts on
                            // outcome, but values must carry the writer id
                            let sk = rng.below(64);
                            if i % 3 == 0 {
                                s.insert(sk, sk);
                            } else if let Some(v) = s.get(sk) {
                                assert_eq!(v, sk, "shared key {sk} tore");
                            }
                        }
                        mine.len() as u64
                    });
                }
            });
            s.check_invariants()
                .unwrap_or_else(|e| panic!("{mode:?} K={cap} quiescent validation: {e}"));
        }
    }
}

/// Concurrent fused runs from several threads over disjoint key stripes
/// (the owner-side combining shape), then full validation — exercises
/// chunk split/merge under the run path's window gating concurrently.
#[test]
fn fatleaf_concurrent_fused_runs() {
    let per_run = scaled(4_000).min(2_000) as usize;
    for cap in [8usize, 16] {
        let s = Arc::new(DetSkiplist::with_leaf_cap_on(
            FindMode::LockFree,
            1 << 16,
            ArenaOptions::default(),
            cap,
        ));
        let threads = 4u64;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let s = s.clone();
                scope.spawn(move || {
                    let base = (t + 1) << 40;
                    let mut rng = Rng::new(0xF00D + t);
                    for round in 0..6u64 {
                        let mut run: Vec<BatchOp> = (0..per_run)
                            .map(|_| {
                                let k = base + rng.below(per_run as u64 * 2);
                                if round % 2 == 0 || rng.below(3) > 0 {
                                    BatchOp::Insert(k, t)
                                } else {
                                    BatchOp::Erase(k)
                                }
                            })
                            .collect();
                        run.sort_by_key(|op| op.key());
                        let mut applied = 0u64;
                        s.apply_sorted_run(&run, &mut |_, r| {
                            if let BatchReply::Applied(true) = r {
                                applied += 1;
                            }
                        });
                        let _ = applied;
                    }
                });
            }
        });
        let keys = s.check_invariants().expect("post-run validation");
        assert_eq!(keys.len() as u64, s.len(), "walk vs len");
        // every surviving key must carry its stripe owner's id
        for &k in keys.iter() {
            let owner = (k >> 40) - 1;
            assert_eq!(s.get(k), Some(owner), "key {k} crossed stripes");
        }
    }
}
