//! Concurrency stress: oversubscribed-thread interleavings over every
//! structure, with quiescent oracle validation and linearizability-style
//! per-key checks.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use cdskl::hashtable::{
    ConcurrentMap, SpoHashMap, TbbLikeHashMap, TwoLevelHashMap, TwoLevelSpoHashMap,
};
use cdskl::queue::{ConcurrentQueue, LfQueue};
use cdskl::skiplist::{DetSkiplist, FindMode, RandomSkiplist};
use cdskl::util::rng::Rng;

/// Per-key "last writer wins a token" check: each key is inserted by
/// exactly one thread; finds must never see a value from the wrong thread.
#[test]
fn det_skiplist_values_never_tear_across_threads() {
    let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
    let threads = 8u64;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let s = s.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(t);
                for i in 0..1_500u64 {
                    let k = t * 1_000_000 + i; // disjoint per thread
                    assert!(s.insert(k, t));
                    // immediately visible to self
                    assert_eq!(s.get(k), Some(t), "read-own-write {k}");
                    // random cross-thread reads must return the owner value
                    let other = rng.below(threads);
                    let ok = other * 1_000_000 + rng.below(i + 1);
                    if let Some(v) = s.get(ok) {
                        assert_eq!(v, other, "key {ok} carried wrong owner");
                    }
                }
            });
        }
    });
    assert_eq!(s.len(), threads * 1_500);
    s.check_invariants().unwrap();
}

/// Insert/erase churn on a tiny key space (maximum rebalance pressure),
/// then a quiescent full validation.
#[test]
fn det_skiplist_churn_tiny_keyspace() {
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        let s = Arc::new(DetSkiplist::with_capacity(mode, 1 << 16));
        std::thread::scope(|scope| {
            for t in 0..6u64 {
                let s = s.clone();
                scope.spawn(move || {
                    let mut rng = Rng::new(t + 1000);
                    for _ in 0..4_000 {
                        let k = rng.below(64); // brutal contention
                        match rng.below(3) {
                            0 => {
                                s.insert(k, k);
                            }
                            1 => {
                                s.erase(k);
                            }
                            _ => {
                                s.contains(k);
                            }
                        }
                    }
                });
            }
        });
        let keys = s.check_invariants().unwrap_or_else(|e| panic!("{mode:?}: {e}"));
        assert!(keys.iter().all(|&k| k < 64));
        let st = s.stats();
        assert!(st.splits > 0 || s.len() < 5);
    }
}

/// The randomized skiplist under the same churn.
#[test]
fn random_skiplist_churn_tiny_keyspace() {
    let s = Arc::new(RandomSkiplist::with_capacity(1 << 16));
    std::thread::scope(|scope| {
        for t in 0..6u64 {
            let s = s.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(t + 2000);
                for _ in 0..4_000 {
                    let k = rng.below(64);
                    match rng.below(3) {
                        0 => {
                            s.insert(k, k * 3);
                        }
                        1 => {
                            s.erase(k);
                        }
                        _ => {
                            if let Some(v) = s.get(k) {
                                assert_eq!(v, k * 3);
                            }
                        }
                    }
                }
            });
        }
    });
    s.check_invariants().unwrap();
}

/// Elements pushed by producers are popped exactly once across consumers,
/// per-producer FIFO order preserved (checked via sequence numbers).
#[test]
fn queue_mpmc_exactly_once_with_order() {
    let q = Arc::new(LfQueue::with_config(128, 256, true));
    let producers = 4u64;
    let per = 10_000u64;
    let popped = Arc::new(AtomicU64::new(0));
    let seen: Arc<Vec<AtomicU64>> =
        Arc::new((0..producers).map(|_| AtomicU64::new(0)).collect());
    std::thread::scope(|scope| {
        for p in 0..producers {
            let q = q.clone();
            scope.spawn(move || {
                for i in 0..per {
                    q.push(p << 48 | i);
                }
            });
        }
        for _ in 0..4 {
            let q = q.clone();
            let popped = popped.clone();
            let seen = seen.clone();
            scope.spawn(move || {
                loop {
                    match q.pop() {
                        Some(v) => {
                            let p = (v >> 48) as usize;
                            let i = v & 0xFFFF_FFFF_FFFF;
                            // per-producer sequence must be non-decreasing
                            // *as observed by any single consumer is not
                            // guaranteed*, but the max must never exceed per
                            assert!(i < per);
                            seen[p].fetch_max(i + 1, Ordering::Relaxed);
                            popped.fetch_add(1, Ordering::Relaxed);
                        }
                        None => {
                            if popped.load(Ordering::Relaxed) >= producers * per {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
            });
        }
    });
    assert_eq!(popped.load(Ordering::Relaxed), producers * per);
    for p in 0..producers as usize {
        assert_eq!(seen[p].load(Ordering::Relaxed), per);
    }
    let st = q.stats();
    assert_eq!(st.pushes, producers * per);
    assert_eq!(st.pops, producers * per);
}

/// All hash tables under concurrent disjoint writers + racing readers.
#[test]
fn hash_tables_concurrent_readers_writers() {
    fn stress<M: ConcurrentMap + 'static>(m: Arc<M>) {
        let writers = 4u64;
        let per = 2_000u64;
        std::thread::scope(|scope| {
            for t in 0..writers {
                let m = m.clone();
                scope.spawn(move || {
                    for i in 0..per {
                        let k = t * 10_000_000 + i;
                        assert!(m.insert(k, k ^ 0xBEEF), "{} insert {k}", m.name());
                    }
                });
            }
            for _ in 0..2 {
                let m = m.clone();
                scope.spawn(move || {
                    let mut rng = Rng::new(99);
                    for _ in 0..4_000 {
                        let t = rng.below(writers);
                        let i = rng.below(per);
                        let k = t * 10_000_000 + i;
                        if let Some(v) = m.get(k) {
                            assert_eq!(v, k ^ 0xBEEF, "{} torn value at {k}", m.name());
                        }
                    }
                });
            }
        });
        assert_eq!(m.len(), writers * per, "{}", m.name());
    }
    stress(Arc::new(TwoLevelHashMap::new(16, 32)));
    stress(Arc::new(SpoHashMap::with_config(16, 4, 1 << 12, 1 << 15)));
    stress(Arc::new(TwoLevelSpoHashMap::with_config(8, 8, 4, 1 << 10, 1 << 13)));
    stress(Arc::new(TbbLikeHashMap::with_config(16, 2)));
}

/// Failure injection: a "slow" thread that sleeps mid-stream must not
/// stall others (lock-free find / queue progress) or corrupt state.
#[test]
fn slow_thread_does_not_corrupt() {
    let s = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
    let q = Arc::new(LfQueue::with_config(64, 128, true));
    std::thread::scope(|scope| {
        // slow mutator: sleeps between ops
        let s2 = s.clone();
        let q2 = q.clone();
        scope.spawn(move || {
            for i in 0..50u64 {
                s2.insert(i, i);
                q2.push(i);
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        });
        // fast workers proceed
        for t in 1..4u64 {
            let s = s.clone();
            let q = q.clone();
            scope.spawn(move || {
                for i in 0..5_000u64 {
                    let k = t * 100_000 + i;
                    s.insert(k, k);
                    q.push(k);
                    q.pop();
                    s.contains(k);
                }
            });
        }
    });
    let keys: BTreeSet<u64> = s.check_invariants().unwrap().into_iter().collect();
    for i in 0..50 {
        assert!(keys.contains(&i), "slow thread's key {i} lost");
    }
}
