//! Property tests over the data-structure invariants (mini-prop harness —
//! proptest is unavailable offline; failures shrink and report seeds).

use std::collections::BTreeMap;

use cdskl::coordinator::{OrderedKv, StoreKind};
use cdskl::hashtable::{
    ConcurrentMap, FixedHashMap, SpoHashMap, TbbLikeHashMap, TwoLevelHashMap, TwoLevelSpoHashMap,
};
use cdskl::mem::NodePool;
use cdskl::queue::{ConcurrentQueue, LfQueue, MsQueue};
use cdskl::skiplist::{DetSkiplist, FindMode, RandomSkiplist};
use cdskl::util::miniprop::{forall_ops, forall_vec_u64, Op};

/// Any op-sequence applied to the det skiplist matches a BTreeMap oracle,
/// and the 1-2-3-4 structure invariants hold afterwards.
#[test]
fn det_skiplist_matches_oracle_on_any_history() {
    forall_ops(0xD5, 60, 400, 128, (40, 40), |ops| {
        let s = DetSkiplist::with_capacity(FindMode::LockFree, 1 << 14);
        let mut oracle = BTreeMap::new();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                Op::Insert(k) => {
                    let fresh = !oracle.contains_key(&k);
                    if s.insert(k, k * 2) != fresh {
                        return Err(format!("op {i}: insert({k}) disagreed"));
                    }
                    oracle.entry(k).or_insert(k * 2);
                }
                Op::Find(k) => {
                    if s.get(k) != oracle.get(&k).copied() {
                        return Err(format!("op {i}: get({k}) disagreed"));
                    }
                }
                Op::Erase(k) => {
                    if s.erase(k) != oracle.remove(&k).is_some() {
                        return Err(format!("op {i}: erase({k}) disagreed"));
                    }
                }
            }
        }
        let keys = s.check_invariants().map_err(|e| format!("invariants: {e}"))?;
        if keys != oracle.keys().copied().collect::<Vec<_>>() {
            return Err("terminal keys != oracle keys".into());
        }
        Ok(())
    });
}

#[test]
fn det_skiplist_rwl_matches_oracle_on_any_history() {
    forall_ops(0xD6, 30, 300, 64, (40, 40), |ops| {
        let s = DetSkiplist::with_capacity(FindMode::ReadLocked, 1 << 14);
        let mut oracle = BTreeMap::new();
        for op in ops {
            match *op {
                Op::Insert(k) => {
                    let fresh = !oracle.contains_key(&k);
                    if s.insert(k, k) != fresh {
                        return Err(format!("insert({k}) disagreed"));
                    }
                    oracle.entry(k).or_insert(k);
                }
                Op::Find(k) => {
                    if s.contains(k) != oracle.contains_key(&k) {
                        return Err(format!("find({k}) disagreed"));
                    }
                }
                Op::Erase(k) => {
                    if s.erase(k) != oracle.remove(&k).is_some() {
                        return Err(format!("erase({k}) disagreed"));
                    }
                }
            }
        }
        s.check_invariants().map_err(|e| format!("invariants: {e}"))?;
        Ok(())
    });
}

#[test]
fn random_skiplist_matches_oracle_on_any_history() {
    forall_ops(0xD7, 40, 400, 128, (40, 40), |ops| {
        let s = RandomSkiplist::with_capacity(1 << 14);
        let mut oracle = BTreeMap::new();
        for op in ops {
            match *op {
                Op::Insert(k) => {
                    let fresh = !oracle.contains_key(&k);
                    if s.insert(k, k) != fresh {
                        return Err(format!("insert({k}) disagreed"));
                    }
                    oracle.entry(k).or_insert(k);
                }
                Op::Find(k) => {
                    if s.contains(k) != oracle.contains_key(&k) {
                        return Err(format!("find({k}) disagreed"));
                    }
                }
                Op::Erase(k) => {
                    if s.erase(k) != oracle.remove(&k).is_some() {
                        return Err(format!("erase({k}) disagreed"));
                    }
                }
            }
        }
        let keys = s.check_invariants().map_err(|e| format!("invariants: {e}"))?;
        if keys != oracle.keys().copied().collect::<Vec<_>>() {
            return Err("level-0 keys != oracle keys".into());
        }
        Ok(())
    });
}

/// Every hash-table variant agrees with the oracle on any history.
#[test]
fn hash_tables_match_oracle_on_any_history() {
    fn check<M: ConcurrentMap>(make: impl Fn() -> M, seed: u64) {
        forall_ops(seed, 25, 300, 200, (40, 40), |ops| {
            let m = make();
            let mut oracle = BTreeMap::new();
            for op in ops {
                match *op {
                    Op::Insert(k) => {
                        let fresh = !oracle.contains_key(&k);
                        if m.insert(k, k + 1) != fresh {
                            return Err(format!("{}: insert({k})", m.name()));
                        }
                        oracle.entry(k).or_insert(k + 1);
                    }
                    Op::Find(k) => {
                        if m.get(k) != oracle.get(&k).copied() {
                            return Err(format!("{}: get({k})", m.name()));
                        }
                    }
                    Op::Erase(k) => {
                        if m.erase(k) != oracle.remove(&k).is_some() {
                            return Err(format!("{}: erase({k})", m.name()));
                        }
                    }
                }
            }
            if m.len() as usize != oracle.len() {
                return Err(format!("{}: len mismatch", m.name()));
            }
            Ok(())
        });
    }
    check(|| FixedHashMap::new(16), 0xA1);
    check(|| TwoLevelHashMap::new(4, 8), 0xA2);
    check(|| SpoHashMap::with_config(4, 2, 1 << 10, 1 << 14), 0xA3);
    check(|| TwoLevelSpoHashMap::with_config(4, 4, 2, 1 << 10, 1 << 13), 0xA4);
    check(|| TbbLikeHashMap::with_config(4, 2), 0xA5);
}

/// Queue: any push/pop interleaving preserves the multiset and FIFO order.
#[test]
fn queue_is_fifo_on_any_sequence() {
    forall_vec_u64(0x51, 80, 600, u64::MAX, |ops| {
        // interpret values: even = push(v), odd = pop
        let q = LfQueue::with_config(8, 32, true);
        let mut model = std::collections::VecDeque::new();
        for &v in ops {
            if v % 2 == 0 {
                q.push(v);
                model.push_back(v);
            } else {
                let got = q.pop();
                let want = model.pop_front();
                if got != want {
                    return Err(format!("pop: got {got:?} want {want:?}"));
                }
            }
        }
        // drain: remaining contents must match exactly
        while let Some(want) = model.pop_front() {
            match q.pop() {
                Some(got) if got == want => {}
                other => return Err(format!("drain: got {other:?} want {want}")),
            }
        }
        if q.pop().is_some() {
            return Err("queue should be empty".into());
        }
        Ok(())
    });
}

#[test]
fn ms_queue_is_fifo_on_any_sequence() {
    forall_vec_u64(0x52, 40, 400, u64::MAX, |ops| {
        let q = MsQueue::with_block_size(8);
        let mut model = std::collections::VecDeque::new();
        for &v in ops {
            if v % 2 == 0 {
                q.push(v);
                model.push_back(v);
            } else if q.pop() != model.pop_front() {
                return Err("pop mismatch".into());
            }
        }
        while let Some(want) = model.pop_front() {
            if q.pop() != Some(want) {
                return Err("drain mismatch".into());
            }
        }
        Ok(())
    });
}

/// Memory pool: unique addresses, eq.5-style block accounting bounds.
#[test]
fn pool_block_accounting_bounds_on_any_sequence() {
    forall_vec_u64(0x53, 60, 400, u64::MAX, |ops| {
        let c = 8u64;
        let pool: NodePool<u64> = NodePool::new(c as usize, 256);
        let mut live = Vec::new();
        let mut peak_live = 0u64;
        for &v in ops {
            if v % 2 == 0 || live.is_empty() {
                let p = pool.alloc();
                if live.contains(&(p as usize)) {
                    return Err("pool returned a live address".into());
                }
                live.push(p as usize);
                peak_live = peak_live.max(live.len() as u64);
            } else {
                let p = live.swap_remove((v as usize / 2) % live.len());
                pool.retire(p as *mut _);
            }
        }
        let st = pool.stats();
        // §V bound: blocks <= ceil(peak_live / C) (+1 slack for recycle races)
        if st.blocks > peak_live.div_ceil(c) + 1 {
            return Err(format!("blocks {} exceed bound for peak {peak_live}", st.blocks));
        }
        Ok(())
    });
}

/// The ordered-map API (`range` / `insert_batch` / `erase_batch`) agrees
/// with a BTreeMap oracle on any history, for every one of the seven
/// structures behind `StoreKind` (skiplists answer natively, hash tables
/// via the sorted-snapshot fallback).
#[test]
fn ordered_api_matches_btreemap_oracle_on_all_structures() {
    fn check(kind: StoreKind, seed: u64) {
        forall_ops(seed, 10, 220, 96, (45, 20), |ops| {
            let s: Box<dyn OrderedKv> = kind.build(1 << 14);
            let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
            for (i, op) in ops.iter().enumerate() {
                match *op {
                    // Insert ops become a 3-pair batch around k. Every pair
                    // carries value = key + 1, so intra-batch duplicate keys
                    // cannot make the (sorted) native batch path and the
                    // sequential oracle disagree on values.
                    Op::Insert(k) => {
                        let batch = [(k, k + 1), (k ^ 7, (k ^ 7) + 1), (k + 13, k + 14)];
                        let mut fresh = 0;
                        for &(bk, bv) in &batch {
                            if !oracle.contains_key(&bk) {
                                oracle.insert(bk, bv);
                                fresh += 1;
                            }
                        }
                        let got = s.insert_batch(&batch);
                        if got != fresh {
                            return Err(format!(
                                "{}: op {i} insert_batch({k}): got {got} want {fresh}",
                                s.name()
                            ));
                        }
                    }
                    // Find ops become a window range query around k.
                    Op::Find(k) => {
                        let (lo, hi) = (k.saturating_sub(16), k + 16);
                        let got = s.range(lo, hi);
                        let want: Vec<(u64, u64)> =
                            oracle.range(lo..=hi).map(|(&a, &b)| (a, b)).collect();
                        if got != want {
                            return Err(format!(
                                "{}: op {i} range({lo},{hi}): got {} want {} rows",
                                s.name(),
                                got.len(),
                                want.len()
                            ));
                        }
                    }
                    // Erase ops become a 2-key batch.
                    Op::Erase(k) => {
                        let keys = [k, k + 13];
                        let mut hit = 0;
                        for bk in keys {
                            if oracle.remove(&bk).is_some() {
                                hit += 1;
                            }
                        }
                        let got = s.erase_batch(&keys);
                        if got != hit {
                            return Err(format!(
                                "{}: op {i} erase_batch({k}): got {got} want {hit}",
                                s.name()
                            ));
                        }
                    }
                }
            }
            // full sweep: the whole map, sorted, exactly once per key
            let got = s.range(0, u64::MAX - 2);
            let want: Vec<(u64, u64)> = oracle.iter().map(|(&a, &b)| (a, b)).collect();
            if got != want {
                return Err(format!("{}: full-range sweep != oracle", s.name()));
            }
            if s.len() as usize != oracle.len() {
                return Err(format!("{}: len mismatch", s.name()));
            }
            // inverted bounds are empty
            if !s.range(10, 9).is_empty() {
                return Err(format!("{}: inverted bounds must be empty", s.name()));
            }
            Ok(())
        });
    }
    let kinds = [
        StoreKind::DetSkiplistLf,
        StoreKind::DetSkiplistRwl,
        StoreKind::RandomSkiplist,
        StoreKind::HashFixed,
        StoreKind::HashTwoLevel,
        StoreKind::HashSpo,
        StoreKind::HashTwoLevelSpo,
        StoreKind::HashTbbLike,
    ];
    for (i, kind) in kinds.into_iter().enumerate() {
        check(kind, 0xE0 + i as u64);
    }
}

/// Range queries agree with the oracle on arbitrary contents and bounds.
#[test]
fn skiplist_range_matches_oracle() {
    forall_vec_u64(0x54, 40, 300, 1 << 16, |keys| {
        let s = DetSkiplist::with_capacity(FindMode::LockFree, 1 << 14);
        let mut oracle = BTreeMap::new();
        for &k in keys {
            s.insert(k, k + 7);
            oracle.entry(k).or_insert(k + 7);
        }
        for (lo, hi) in [(0u64, 1 << 16), (100, 50), (1 << 10, 1 << 12), (7, 7)] {
            let got = s.range(lo, hi);
            let want: Vec<(u64, u64)> =
                oracle.range(lo..=hi.max(lo).min(u64::MAX - 2)).map(|(&k, &v)| (k, v)).collect();
            let want = if hi < lo { Vec::new() } else { want };
            if got != want {
                return Err(format!("range({lo},{hi}): got {} want {} rows", got.len(), want.len()));
            }
        }
        Ok(())
    });
}
