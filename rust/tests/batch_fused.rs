//! Fused sorted-batch correctness tests (run in CI as the release batch
//! stress step: `CDSKL_SCALE=... cargo test --release -q batch_`).
//!
//! The fused paths — `apply_sorted_run` on both skiplists, the per-key
//! defaults on the hash tables, and the sharded store's segment-routed
//! batch ops — must agree exactly with a sequential `BTreeMap` oracle on
//! every `StoreKind`, for unsorted input, duplicate keys, shard-boundary
//! keys and empty/singleton runs; survive fused-batch vs point-op
//! interleaving on both `DetSkiplist` find modes; and strictly cut node
//! dereferences per op against the per-key loop.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use cdskl::coordinator::{OrderedKv, ShardedStore, StoreKind};
// The canonical 8-kind list, shared with Table XI so the two can't drift.
use cdskl::experiments::hier::T11_KINDS as ALL_KINDS;
use cdskl::numa::Topology;
use cdskl::skiplist::{BatchOp, BatchReply, DetSkiplist, FindMode};
use cdskl::util::rng::Rng;

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness.
fn scaled(n: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (n / scale.max(1)).clamp(200, 100_000)
}

/// Acceptance: `insert_batch`/`get_batch`/`erase_batch` agree with a
/// sequential oracle on every structure — unsorted input, duplicate keys,
/// misses, round after round.
#[test]
fn batch_ops_match_btreemap_oracle_all_kinds() {
    let per_round = scaled(8_000).min(2_000);
    for (ki, kind) in ALL_KINDS.into_iter().enumerate() {
        let s = kind.build(1 << 14);
        let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
        let mut rng = Rng::new(0xBA7C + ki as u64);
        for round in 0..6 {
            // unsorted insert batch with duplicate keys (value = f(key), so
            // dup-order inside the sort is observationally irrelevant)
            let items: Vec<(u64, u64)> = (0..per_round)
                .map(|_| {
                    let k = rng.below(600);
                    (k, k ^ 3)
                })
                .collect();
            let fresh: BTreeSet<u64> = items
                .iter()
                .map(|&(k, _)| k)
                .filter(|k| !oracle.contains_key(k))
                .collect();
            assert_eq!(
                s.insert_batch(&items),
                fresh.len() as u64,
                "{kind:?} round {round}: insert_batch count"
            );
            for &(k, v) in &items {
                oracle.entry(k).or_insert(v);
            }
            // unsorted lookup batch incl. misses and duplicates
            let keys: Vec<u64> = (0..150).map(|_| rng.below(800)).collect();
            let got = s.get_batch(&keys);
            assert_eq!(got.len(), keys.len(), "{kind:?}");
            for (i, &k) in keys.iter().enumerate() {
                assert_eq!(got[i], oracle.get(&k).copied(), "{kind:?} round {round} get {k}");
            }
            // unsorted erase batch with duplicates (each key erases once)
            let eks: Vec<u64> = (0..per_round / 2).map(|_| rng.below(700)).collect();
            let present: BTreeSet<u64> =
                eks.iter().copied().filter(|k| oracle.contains_key(k)).collect();
            assert_eq!(
                s.erase_batch(&eks),
                present.len() as u64,
                "{kind:?} round {round}: erase_batch count"
            );
            for k in &eks {
                oracle.remove(k);
            }
        }
        let want: Vec<(u64, u64)> = oracle.iter().map(|(k, v)| (*k, *v)).collect();
        assert_eq!(s.range(0, u64::MAX - 2), want, "{kind:?}: end state");
    }
}

/// Acceptance: `apply_sorted_run` replies exactly like the sequential
/// per-key replay on every structure (mixed ops, duplicate keys).
#[test]
fn batch_sorted_run_replies_match_sequential_replay() {
    let n_ops = scaled(4_000).min(1_500) as usize;
    for (ki, kind) in ALL_KINDS.into_iter().enumerate() {
        let s = kind.build(1 << 14);
        let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
        let mut rng = Rng::new(0x50B7ED + ki as u64);
        for k in 0..100u64 {
            assert!(s.insert(k * 5, k));
            oracle.insert(k * 5, k);
        }
        for round in 0..4 {
            let mut ops: Vec<BatchOp> = (0..n_ops)
                .map(|_| {
                    let k = rng.below(600);
                    match rng.below(3) {
                        0 => BatchOp::Insert(k, k ^ 11),
                        1 => BatchOp::Erase(k),
                        _ => BatchOp::Get(k),
                    }
                })
                .collect();
            ops.sort_by_key(|o| o.key()); // stable: dup keys keep op order
            let mut got: Vec<Option<BatchReply>> = vec![None; ops.len()];
            s.apply_sorted_run(&ops, &mut |i, r| {
                assert!(got[i].is_none(), "{kind:?}: sink fired twice for op {i}");
                got[i] = Some(r);
            });
            for (i, op) in ops.iter().enumerate() {
                let want = match *op {
                    BatchOp::Insert(k, v) => {
                        let fresh = !oracle.contains_key(&k);
                        if fresh {
                            oracle.insert(k, v);
                        }
                        BatchReply::Applied(fresh)
                    }
                    BatchOp::Erase(k) => BatchReply::Applied(oracle.remove(&k).is_some()),
                    BatchOp::Get(k) => BatchReply::Value(oracle.get(&k).copied()),
                };
                assert_eq!(got[i], Some(want), "{kind:?} round {round} op {i} {op:?}");
            }
        }
        let want: Vec<(u64, u64)> = oracle.iter().map(|(k, v)| (*k, *v)).collect();
        assert_eq!(s.range(0, u64::MAX - 2), want, "{kind:?}: end state");
    }
}

/// Shard-boundary keys, folded shards, empty and singleton runs through
/// the sharded store's segment routing.
#[test]
fn batch_shard_boundaries_folds_and_degenerate_runs() {
    for kind in [StoreKind::DetSkiplistLf, StoreKind::RandomSkiplist, StoreKind::HashFixed] {
        for nshards in [1usize, 2, 4, 8] {
            let s = ShardedStore::new(kind, nshards, 1 << 12, Topology::milan_virtual(), 8);
            // degenerate runs first
            assert_eq!(s.insert_batch(&[]), 0, "{kind:?}/{nshards}");
            assert_eq!(s.erase_batch(&[]), 0);
            assert_eq!(s.get_batch(&[]), Vec::<Option<u64>>::new());
            assert_eq!(s.insert_batch(&[(42, 1)]), 1);
            assert_eq!(s.get_batch(&[42]), vec![Some(1)]);
            assert_eq!(s.erase_batch(&[42]), 1);
            // boundary keys: first/last key of every 3-MSB prefix segment
            let mut items = Vec::new();
            for p in 0..8u64 {
                items.push((p << 61, p + 1));
                items.push((p << 61 | ((1u64 << 61) - 1) - 1, p + 100)); // MAX_KEY-safe
                items.push((p << 61 | 12345, p + 200));
            }
            items.sort_unstable_by_key(|e| e.0);
            assert_eq!(s.insert_batch(&items), items.len() as u64, "{kind:?}/{nshards}");
            let keys: Vec<u64> = items.iter().map(|&(k, _)| k).collect();
            let got = s.get_batch(&keys);
            for (i, &(k, v)) in items.iter().enumerate() {
                assert_eq!(got[i], Some(v), "{kind:?}/{nshards} boundary key {k:#x}");
            }
            assert_eq!(s.range(0, u64::MAX - 2).len(), items.len());
            assert_eq!(s.erase_batch(&keys), keys.len() as u64);
            assert_eq!(s.len(), 0, "{kind:?}/{nshards}");
        }
    }
}

/// Fused batches racing point ops on both find modes: stable keys must
/// never be lost and the structure must stay invariant-clean.
#[test]
fn batch_fused_vs_point_interleaving_lf_and_rwl() {
    let rounds = scaled(2_400).min(40);
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        let s = Arc::new(DetSkiplist::with_capacity(mode, 1 << 16));
        for k in 0..1_000u64 {
            s.insert(k * 10 + 9, k); // stable keys, never touched below
        }
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..rounds {
                    let base = ((t * 500 + round * 13 % 400) * 10) as u64;
                    // unsorted input: exercises the sort-then-fuse path too
                    let mut items: Vec<(u64, u64)> =
                        (0..64u64).map(|j| (base + j * 10 + 1 + t, j)).collect();
                    if round % 2 == 1 {
                        items.reverse();
                    }
                    OrderedKv::insert_batch(&*s, &items);
                    let keys: Vec<u64> = items.iter().map(|&(k, _)| k).collect();
                    OrderedKv::erase_batch(&*s, &keys);
                }
            }));
        }
        for _ in 0..2 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(77);
                for _ in 0..4_000 {
                    let k = rng.below(1_000) * 10 + 9;
                    assert!(s.contains(k), "stable key {k} lost under fused churn");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let keys = s.check_invariants().unwrap();
        assert_eq!(
            keys.iter().filter(|&&k| k % 10 == 9).count(),
            1_000,
            "{mode:?}: stable keys survive"
        );
    }
}

/// Acceptance: the fused batch path does strictly fewer node derefs/op
/// than the per-key loop on clustered sorted batches (the Table XIII bar
/// at store level).
#[test]
fn batch_fused_strictly_cuts_derefs() {
    let mk = || ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 14, Topology::milan_virtual(), 8);
    let fused = mk();
    let per_key = mk();
    let batches: Vec<Vec<(u64, u64)>> = (0..64u64)
        .map(|b| {
            let base = (b % 8) << 61 | (b * 131);
            (0..64u64).map(|j| (base + j, j ^ 5)).collect()
        })
        .collect();
    for batch in &batches {
        fused.insert_batch(batch);
        for &(k, v) in batch {
            per_key.insert(k, v);
        }
    }
    for batch in &batches {
        let keys: Vec<u64> = batch.iter().map(|&(k, _)| k).collect();
        let _ = fused.get_batch(&keys);
        for &k in &keys {
            let _ = per_key.get(k);
        }
    }
    assert_eq!(fused.len(), per_key.len(), "same resident sets");
    let f = fused.stats().node_derefs;
    let p = per_key.stats().node_derefs;
    assert!(f < p, "fused batches must strictly cut derefs ({f} vs {p})");
}
