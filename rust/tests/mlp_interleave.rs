//! Integration tests for the memory-level-parallel interleaved descent
//! engine (PR 6): oracle agreement of the interleaved vs fused vs point
//! paths across every store kind, the `get_batch` batching-bypass
//! regression, finger-cache interplay (the engine bypasses fingers by
//! design — per-lane run carries replace them), engine-level width
//! pinning, and correctness under concurrent churn.
//!
//! All test names carry the `mlp_` prefix so the CI release-stress step
//! (`cargo test --release mlp_`) picks up the whole file.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cdskl::coordinator::{
    run_with_opts, ExecMode, RunOptions, ShardedStore, StoreKind,
};
use cdskl::numa::Topology;
use cdskl::runtime::KeyRouter;
use cdskl::skiplist::{BatchOp, BatchReply};
use cdskl::util::rng::mix64;
use cdskl::workload::{OpMix, WorkloadSpec};

const ALL_KINDS: [StoreKind; 8] = [
    StoreKind::DetSkiplistLf,
    StoreKind::DetSkiplistRwl,
    StoreKind::RandomSkiplist,
    StoreKind::HashFixed,
    StoreKind::HashTwoLevel,
    StoreKind::HashSpo,
    StoreKind::HashTwoLevelSpo,
    StoreKind::HashTbbLike,
];

/// A deterministic key-sorted mixed run (unique keys, so reply semantics
/// are path-independent) plus the oracle outcome of applying it to `map`.
fn mixed_run(seed: u64, n: usize, map: &BTreeMap<u64, u64>) -> (Vec<BatchOp>, Vec<BatchReply>) {
    let mut keys: Vec<u64> = (0..n as u64).map(|i| mix64(seed + i) % (1 << 20)).collect();
    keys.sort_unstable();
    keys.dedup();
    let ops: Vec<BatchOp> = keys
        .iter()
        .map(|&k| match mix64(seed ^ k) % 3 {
            0 => BatchOp::Insert(k, k ^ 0xBEEF),
            1 => BatchOp::Get(k),
            _ => BatchOp::Erase(k),
        })
        .collect();
    let mut oracle = map.clone();
    let want: Vec<BatchReply> = ops
        .iter()
        .map(|op| match *op {
            BatchOp::Insert(k, v) => {
                BatchReply::Applied(oracle.insert(k, v).map(|old| oracle.insert(k, old)).is_none())
            }
            BatchOp::Get(k) => BatchReply::Value(oracle.get(&k).copied()),
            BatchOp::Erase(k) => BatchReply::Applied(oracle.remove(&k).is_some()),
        })
        .collect();
    (ops, want)
}

fn seed_items(seed: u64, n: u64) -> Vec<(u64, u64)> {
    let mut items: Vec<(u64, u64)> =
        (0..n).map(|i| (mix64(seed ^ (i << 32)) % (1 << 20), i + 1)).collect();
    items.sort_unstable_by_key(|e| e.0);
    items.dedup_by_key(|e| e.0);
    items
}

/// Tentpole + satellite 4: on every store kind, the interleaved path (at
/// several widths, including the serialized width-1 lane) agrees reply-
/// for-reply with the fused sorted-run path, the point loop, and a
/// BTreeMap oracle — and leaves identical final state.
#[test]
fn mlp_oracle_agreement_interleaved_vs_fused_vs_point_all_kinds() {
    for kind in ALL_KINDS {
        for width in [1usize, 3, 8, 32] {
            let items = seed_items(0xA11CE, 600);
            let base: BTreeMap<u64, u64> = items.iter().copied().collect();
            let (ops, want) = mixed_run(0xF00D, 400, &base);

            let inter = kind.build(1 << 12);
            let fused = kind.build(1 << 12);
            let point = kind.build(1 << 12);
            for s in [&inter, &fused, &point] {
                for &(k, v) in &items {
                    assert!(s.insert(k, v), "{kind:?} seed {k}");
                }
            }

            let mut got = vec![None; ops.len()];
            inter.apply_interleaved(&ops, width, &mut |i, r| got[i] = Some(r));
            let mut got_fused = vec![None; ops.len()];
            fused.apply_sorted_run(&ops, &mut |i, r| got_fused[i] = Some(r));
            for (i, op) in ops.iter().enumerate() {
                let pt = match *op {
                    BatchOp::Insert(k, v) => BatchReply::Applied(point.insert(k, v)),
                    BatchOp::Get(k) => BatchReply::Value(point.get(k)),
                    BatchOp::Erase(k) => BatchReply::Applied(point.erase(k)),
                };
                assert_eq!(got[i], Some(want[i]), "{kind:?} w{width} op {i} interleaved");
                assert_eq!(got_fused[i], Some(want[i]), "{kind:?} op {i} fused");
                assert_eq!(pt, want[i], "{kind:?} op {i} point");
            }
            // identical final state under every path
            let mut oracle = base.clone();
            for op in &ops {
                match *op {
                    BatchOp::Insert(k, v) => {
                        oracle.entry(k).or_insert(v);
                    }
                    BatchOp::Get(_) => {}
                    BatchOp::Erase(k) => {
                        oracle.remove(&k);
                    }
                }
            }
            assert_eq!(inter.len(), oracle.len() as u64, "{kind:?} w{width}");
            for (&k, &v) in &oracle {
                assert_eq!(inter.get(k), Some(v), "{kind:?} w{width} key {k}");
                assert_eq!(fused.get(k), Some(v), "{kind:?} key {k}");
            }
        }
    }
}

/// Satellite 1 regression: `ShardedStore::get_batch` must not silently
/// bypass batching. A scattered (unsorted) probe set through `get_batch`
/// does strictly fewer hot-line derefs per op than the per-key point
/// loop on an identically seeded store — and returns the same answers in
/// input order.
#[test]
fn mlp_get_batch_beats_point_loop_on_scattered_probes() {
    let topo = Topology::virtual_grid(2, 2);
    let build = || {
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 4, 1 << 15, topo.clone(), 4);
        let items: Vec<(u64, u64)> =
            (0..20_000u64).map(|i| ((i % 8) << 61 | i * 31, i + 1)).collect();
        assert_eq!(s.insert_batch(&items), items.len() as u64);
        s
    };
    // scattered, unsorted, with misses and duplicates
    let probes: Vec<u64> = (0..8_192u64)
        .map(|j| {
            let i = mix64(j) % 20_500;
            (i % 8) << 61 | i * 31
        })
        .collect();

    let point = build();
    let before = point.stats().node_derefs;
    let want: Vec<Option<u64>> = probes.iter().map(|&k| point.get(k)).collect();
    let point_derefs = point.stats().node_derefs - before;

    let batched = build();
    let before = batched.stats().node_derefs;
    let got = batched.get_batch(&probes);
    let batch_derefs = batched.stats().node_derefs - before;

    assert_eq!(got, want, "get_batch must restore input order exactly");
    assert!(
        batch_derefs < point_derefs,
        "scattered get_batch must do strictly fewer derefs than the point loop \
         ({batch_derefs} vs {point_derefs} over {} probes)",
        probes.len()
    );
}

/// The interleaved engine and the per-thread search fingers coexist: the
/// engine deliberately bypasses fingers (per-lane run carries subsume
/// them — documented in DESIGN.md §MLP), so results agree with fingers
/// on or off, and interleaved batches never consult the finger cache.
#[test]
fn mlp_interleaved_agrees_with_fingers_on_and_off() {
    let topo = Topology::virtual_grid(2, 2);
    for fingers in [true, false] {
        let s = ShardedStore::new(StoreKind::DetSkiplistLf, 2, 1 << 14, topo.clone(), 2);
        s.set_finger_cache(fingers);
        let items: Vec<(u64, u64)> = (0..4_000u64).map(|i| ((i % 8) << 61 | i * 7, i)).collect();
        assert_eq!(s.insert_batch(&items), items.len() as u64);
        // warm the fingers through point gets, then batch through the engine
        for &(k, _) in items.iter().take(64) {
            let _ = s.get(k);
        }
        let attempts_before = s.stats().finger_attempts;
        let probes: Vec<u64> = (0..2_048u64)
            .map(|j| {
                let i = mix64(0xF1A6 + j) % 4_000;
                (i % 8) << 61 | i * 7
            })
            .collect();
        let got = s.get_batch(&probes);
        for (j, &k) in probes.iter().enumerate() {
            assert_eq!(got[j], Some((k & ((1 << 61) - 1)) / 7), "fingers={fingers} key {k}");
        }
        assert_eq!(
            s.stats().finger_attempts,
            attempts_before,
            "interleaved batches bypass the finger cache (fingers={fingers})"
        );
    }
}

/// Engine-level wiring: a Delegated run with the interleave width pinned
/// (`run --interleave 8`) quiesces, stays NUMA-local, and lands the same
/// final state as the Direct run of the same workload.
#[test]
fn mlp_engine_run_with_pinned_width_matches_direct() {
    let topo = Topology::virtual_grid(2, 2);
    let spec = WorkloadSpec::new("mlp-pin", 30_000, OpMix::W1, 1 << 22);
    let mk = || Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 15, topo.clone(), 4));

    let direct = mk();
    let md = run_with_opts(
        &direct,
        &spec,
        4,
        &KeyRouter::Native,
        99,
        RunOptions { mode: ExecMode::Direct, ..RunOptions::default() },
    );
    let delegated = mk();
    let mw = run_with_opts(
        &delegated,
        &spec,
        4,
        &KeyRouter::Native,
        99,
        RunOptions { mode: ExecMode::Delegated, interleave: 8, ..RunOptions::default() },
    );
    assert_eq!(mw.fabric.executed, mw.fabric.submitted, "fabric must quiesce");
    assert_eq!(mw.remote_accesses, 0, "delegated execution stays NUMA-local");
    assert_eq!(md.final_len, mw.final_len);
    assert_eq!(
        direct.range(0, u64::MAX - 2),
        delegated.range(0, u64::MAX - 2),
        "pinned-width delegated run must land the Direct final state"
    );
}

/// Satellite 4: scattered batched reads stay correct while writers churn
/// disjoint keys — on both the lock-free find kind (true interleaved
/// engine) and the read-locked kind (documented fused fallback).
#[test]
fn mlp_get_batch_under_concurrent_churn_lf_and_rwl() {
    for kind in [StoreKind::DetSkiplistLf, StoreKind::DetSkiplistRwl] {
        let store = ShardedStore::new(kind, 4, 1 << 14, Topology::virtual_grid(2, 2), 4);
        // stable keys are even multiples; churn keys are odd — disjoint
        let stable: Vec<(u64, u64)> =
            (0..3_000u64).map(|i| ((i % 8) << 61 | i * 4, i + 1)).collect();
        assert_eq!(store.insert_batch(&stable), stable.len() as u64);
        let stop = AtomicBool::new(false);
        std::thread::scope(|sc| {
            for t in 0..2u64 {
                let (store, stop) = (&store, &stop);
                sc.spawn(move || {
                    let mut round = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        for i in 0..500u64 {
                            let k = ((i % 8) << 61) | (i * 4 + 1 + 2 * t);
                            if round & 1 == 0 {
                                store.insert(k, k);
                            } else {
                                store.erase(k);
                            }
                        }
                        round += 1;
                    }
                });
            }
            for r in 0..200u64 {
                let probes: Vec<u64> = (0..512u64)
                    .map(|j| {
                        let i = mix64(r * 512 + j) % 3_000;
                        (i % 8) << 61 | i * 4
                    })
                    .collect();
                let got = store.get_batch(&probes);
                for (j, &k) in probes.iter().enumerate() {
                    let want = (k & ((1 << 61) - 1)) / 4 + 1;
                    assert_eq!(got[j], Some(want), "{kind:?} round {r} key {k}");
                }
            }
            stop.store(true, Ordering::Relaxed);
        });
    }
}
