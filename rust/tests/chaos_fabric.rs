//! Fault-injection integration tests for the self-healing delegation
//! fabric (`--features failpoints`; run in CI as the chaos stress step:
//! `CDSKL_SCALE=... cargo test --release --features failpoints -q chaos_`).
//!
//! Each test installs a seeded [`FaultPlan`] (deterministic: the plan +
//! seed fully determine which hits fire) and asserts the fabric's
//! self-healing contract: an owner killed at an op-envelope boundary loses
//! no work (survivors adopt its queue and shards, every submitted op still
//! settles exactly once), a frozen owner is detected by heartbeat and
//! adopted, wedged synchronous callers get a typed [`FabricError`] instead
//! of a hang or panic, spurious queue-full storms ride the backpressure
//! loop, and a caller-side panic retires one owner without poisoning the
//! fabric for everyone else.

#![cfg(feature = "failpoints")]

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use cdskl::coordinator::{
    run_with_opts, DelegatedOp, ExecMode, FabricError, OpFabric, OpResult, RunOptions,
    ShardedStore, StoreKind,
};
// The canonical 8-kind list, shared with Table XI so the two can't drift.
use cdskl::experiments::hier::T11_KINDS as ALL_KINDS;
use cdskl::numa::{pin_to_cpu, Topology};
use cdskl::runtime::KeyRouter;
use cdskl::util::fail::FaultPlan;
use cdskl::util::rng::Rng;
use cdskl::workload::{OpMix, WorkloadSpec};

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness.
fn scaled_ops(paper_ops: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (paper_ops / scale.max(1)).clamp(800, 200_000)
}

/// Run `body(caller_id, fabric, store)` while `threads` pinned owner
/// threads drain the fabric (same harness as `hier_delegation.rs`, kept
/// local because the fault tests need to poke the fabric mid-run). Owners
/// exit once `body` returns and every queue — including adopted orphan
/// queues — is empty; a cleanly-killed owner's loop survives as an idle
/// spinner until then, exactly like a real worker that stood down.
fn with_owner_pool<R>(
    kind: StoreKind,
    threads: usize,
    topo: Topology,
    batch_n: usize,
    body: impl FnOnce(usize, &OpFabric, &ShardedStore) -> R,
) -> (R, Arc<ShardedStore>, Arc<OpFabric>) {
    let store = Arc::new(ShardedStore::new(kind, 8, 1 << 13, topo.clone(), threads));
    let fabric = Arc::new(OpFabric::new(threads, 2, 8, topo, 64, batch_n));
    let stop = Arc::new(AtomicBool::new(false));
    let out = std::thread::scope(|scope| {
        for t in 0..threads {
            let fabric = fabric.clone();
            let store = store.clone();
            let stop = stop.clone();
            scope.spawn(move || {
                pin_to_cpu(t);
                loop {
                    let n = fabric.drain(t, &store, 16);
                    if n == 0 {
                        if stop.load(Ordering::Acquire) && fabric.pending_batches() == 0 {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            });
        }
        let r = body(threads, &fabric, &store);
        stop.store(true, Ordering::Release);
        r
    });
    (out, store, fabric)
}

/// Spin until every submitted op has settled (executed or error-settled),
/// while the owner pool is still draining.
fn quiesce(fabric: &OpFabric, ctx: &str) {
    let t0 = std::time::Instant::now();
    loop {
        let st = fabric.stats();
        if st.executed + st.errored == st.submitted {
            return;
        }
        assert!(t0.elapsed().as_secs() < 120, "{ctx}: fabric failed to quiesce: {st:?}");
        std::thread::yield_now();
    }
}

/// Acceptance: an owner killed at an op-envelope boundary mid-workload
/// loses nothing, on every store kind — a survivor adopts the dead owner's
/// queue and shards, all submitted ops execute exactly once, no op is
/// error-settled, and final membership agrees with a sequential oracle
/// (insert/find mix: membership is order-independent, so the cross-queue
/// reordering a takeover can introduce is invisible to the oracle).
#[test]
fn chaos_owner_kill_recovers_zero_lost_acks_all_kinds() {
    let ops = scaled_ops(100_000);
    for (i, kind) in ALL_KINDS.into_iter().enumerate() {
        // One kill, early: the site is hit once per drain window, so the
        // 30th hit lands while the workload is still in flight.
        let guard = FaultPlan::new(0xC4_05 + i as u64).kill_nth("fabric.owner.kill", 30).install();
        let ((), store, fabric) = with_owner_pool(
            kind,
            4,
            Topology::virtual_grid(2, 2),
            8,
            |caller_id, fabric, store| {
                let mut caller = fabric.caller(caller_id, None);
                let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
                let mut rng = Rng::new(0xDEAD + i as u64);
                for n in 0..ops {
                    // Distinct keys across all 8 prefixes: every insert is
                    // fresh, so final membership is exactly the oracle.
                    let k = ((n % 8) << 61) | (n >> 3);
                    if rng.below(4) == 0 {
                        caller.delegate(DelegatedOp::Find { key: k ^ 1 }, store);
                    } else {
                        oracle.insert(k, n);
                        caller.delegate(DelegatedOp::Insert { key: k, value: n }, store);
                    }
                }
                caller.finish(store);
                quiesce(fabric, "owner-kill");
                let got = store.range(0, u64::MAX);
                let want: Vec<(u64, u64)> = oracle.iter().map(|(k, v)| (*k, *v)).collect();
                assert_eq!(got, want, "{kind:?}: post-recovery state vs oracle");
            },
        );
        drop(guard);
        let st = fabric.stats();
        assert_eq!(st.owner_deaths, 1, "{kind:?}: exactly the injected kill");
        assert_eq!(st.errored, 0, "{kind:?}: a clean kill loses nothing");
        assert_eq!(st.executed, st.submitted, "{kind:?}: every op settled");
        assert!(st.shards_adopted >= 1, "{kind:?}: the dead owner's shards re-home");
        assert!(st.recovery_ns > 0, "{kind:?}: takeover must be timestamped");
        let totals = fabric.slot_totals(4);
        assert_eq!(totals.acked, st.executed, "{kind:?}: single caller acks everything");
        assert_eq!(totals.errored, 0, "{kind:?}");
        drop(store);
    }
}

/// A synchronous caller on a fabric whose owners never drain must come
/// back typed, twice over: `Timeout` while the owner is merely wedged,
/// `OwnerDead` once the owner has been declared dead — never a panic,
/// never an unbounded spin.
#[test]
fn chaos_sync_call_times_out_on_wedged_owner() {
    let topo = Topology::virtual_grid(1, 2);
    let fabric = OpFabric::new(2, 2, 8, topo.clone(), 16, 4);
    fabric.set_op_timeout(Some(Duration::from_millis(30)));
    let store = ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 14, topo, 2);
    // No drainer threads: the op sits in the owner queue forever.
    let mut wedged = fabric.caller(2, None);
    let r = wedged.call(DelegatedOp::Insert { key: 7, value: 7 }, &store);
    assert!(matches!(r, Err(FabricError::Timeout)), "wedged-but-alive owner: got {r:?}");
    wedged.finish(&store);
    // Declare the key's owner dead: the same wait now discriminates.
    let owner = fabric.owner_of_key(7);
    fabric.mark_owner_dead(owner, true);
    // Fresh caller: the wedged one's slot is still burned (its settler
    // never ran), which is itself part of the abandon contract.
    let mut caller = fabric.caller(3, None);
    let r = caller.call(DelegatedOp::Insert { key: 7, value: 7 }, &store);
    assert!(matches!(r, Err(FabricError::OwnerDead)), "dead owner: got {r:?}");
    caller.finish(&store);
    assert_eq!(fabric.stats().sync_timeouts, 2, "both waits abandoned their slot");
}

/// A frozen owner (never drains, heartbeat never advances) is declared
/// dead by a survivor's liveness sweep and its queued work adopted and
/// executed — no failpoint needed; the freeze is real (the thread is
/// simply never started).
#[test]
fn chaos_heartbeat_detects_frozen_owner_and_adopts() {
    let topo = Topology::virtual_grid(1, 2);
    let store = Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 13, topo.clone(), 2));
    let fabric = Arc::new(OpFabric::new(2, 1, 8, topo, 64, 4));
    fabric.set_owner_dead_after(Some(Duration::from_millis(5)));
    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        // Owner 0 is frozen: its drain loop never runs, its initial beat
        // of 0 goes stale the moment the fabric is 5ms old. Owner 1 is
        // the survivor.
        let f = fabric.clone();
        let s = store.clone();
        let stp = stop.clone();
        scope.spawn(move || loop {
            let n = f.drain(1, &s, 16);
            if n == 0 {
                if stp.load(Ordering::Acquire) && f.pending_batches() == 0 {
                    break;
                }
                std::thread::yield_now();
            }
        });
        let mut caller = fabric.caller(2, None);
        let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
        // Keys across all 8 prefixes: half route to the frozen owner and
        // pile up in its queue until the heartbeat sweep fires.
        for n in 0..scaled_ops(50_000) {
            let k = ((n % 8) << 61) | (n >> 3);
            oracle.insert(k, n);
            caller.delegate(DelegatedOp::Insert { key: k, value: n }, &store);
        }
        caller.finish(&store);
        quiesce(&fabric, "heartbeat");
        stop.store(true, Ordering::Release);
        let got = store.range(0, u64::MAX);
        let want: Vec<(u64, u64)> = oracle.iter().map(|(k, v)| (*k, *v)).collect();
        assert_eq!(got, want, "adopted work must all land");
    });
    let st = fabric.stats();
    assert_eq!(st.owner_deaths, 1, "the frozen owner, declared by heartbeat");
    assert!(st.shards_adopted >= 1, "its shards re-home to the survivor");
    assert!(st.recovery_ns > 0);
    assert_eq!(st.errored, 0);
}

/// Spurious queue-full rejections (injected `try_push` failures) are
/// absorbed by the dispatch backpressure loop: order is preserved, every
/// op executes, and the final state matches an exact sequential oracle —
/// insert/erase included, since nothing dies and per-owner FIFO holds.
#[test]
fn chaos_spurious_queue_full_rides_backpressure() {
    let ops = scaled_ops(100_000);
    let _g = FaultPlan::new(0xF0_11).fail_prob("queue.try_push", 1, 4).install();
    let ((), store, fabric) = with_owner_pool(
        StoreKind::DetSkiplistLf,
        4,
        Topology::virtual_grid(2, 2),
        8,
        |caller_id, fabric, store| {
            let mut caller = fabric.caller(caller_id, None);
            let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
            let mut rng = Rng::new(0xB00);
            for n in 0..ops {
                let k = (rng.below(8) << 61) | rng.below(512);
                if rng.below(3) < 2 {
                    oracle.entry(k).or_insert(n);
                    caller.delegate(DelegatedOp::Insert { key: k, value: n }, store);
                } else {
                    oracle.remove(&k);
                    caller.delegate(DelegatedOp::Erase { key: k }, store);
                }
            }
            caller.finish(store);
            quiesce(fabric, "qfull");
            let got = store.range(0, u64::MAX);
            let want: Vec<(u64, u64)> = oracle.iter().map(|(k, v)| (*k, *v)).collect();
            assert_eq!(got, want, "exact state survives the storm");
        },
    );
    drop(store);
    let st = fabric.stats();
    assert!(st.backpressure > 0, "a 1-in-4 rejection storm must be visible: {st:?}");
    assert_eq!(st.executed, st.submitted);
    assert_eq!(st.owner_deaths, 0, "nothing actually died");
}

/// Slow owners (injected drain-entry delays) and delayed acks (injected
/// settle delays) stretch every window the sync rendezvous has, but with a
/// generous deadline every call still completes `Ok` with oracle-exact
/// results and zero timeouts.
#[test]
fn chaos_slow_owner_and_delayed_ack_complete() {
    let _g = FaultPlan::new(0xF0_22)
        .delay_prob("fabric.owner.slow", 1, 8, 50_000)
        .delay_prob("fabric.settle", 1, 4, 20_000)
        .install();
    let ops = scaled_ops(80_000).min(1_500); // sync round-trips, injected-slow
    let ((), _store, fabric) = with_owner_pool(
        StoreKind::DetSkiplistLf,
        4,
        Topology::virtual_grid(2, 2),
        8,
        |caller_id, fabric, store| {
            fabric.set_op_timeout(Some(Duration::from_secs(5)));
            let mut caller = fabric.caller(caller_id, None);
            let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
            let mut rng = Rng::new(0x51_0E);
            for n in 0..ops {
                let k = (rng.below(8) << 61) | rng.below(256);
                if rng.below(2) == 0 {
                    let fresh = !oracle.contains_key(&k);
                    if fresh {
                        oracle.insert(k, n);
                    }
                    let got = caller
                        .call(DelegatedOp::Insert { key: k, value: n }, store)
                        .expect("slow is not dead: the call must complete");
                    assert_eq!(got, OpResult::Applied(fresh), "insert {k:#x}");
                } else {
                    let got = caller
                        .call(DelegatedOp::Find { key: k }, store)
                        .expect("delayed ack still arrives");
                    assert_eq!(got, OpResult::Value(oracle.get(&k).copied()), "find {k:#x}");
                }
            }
            caller.finish(store);
        },
    );
    let st = fabric.stats();
    assert_eq!(st.sync_timeouts, 0, "generous deadline: nobody abandons");
    assert_eq!(st.owner_deaths, 0, "slow must not be mistaken for dead (no heartbeat armed)");
    assert_eq!(st.executed, st.submitted);
}

/// Transient arena free-list exhaustion (injected refill failure) only
/// diverts allocation to the bump path: insert/erase/insert churn that
/// leans hard on slot recycling still yields exact membership.
#[test]
fn chaos_arena_refill_transient_exhaustion() {
    let _g = FaultPlan::new(0xF0_33).fail_prob("arena.refill", 1, 2).install();
    let store = ShardedStore::new(
        StoreKind::DetSkiplistLf,
        8,
        1 << 13,
        Topology::virtual_grid(1, 2),
        2,
    );
    let n = scaled_ops(50_000).min(6_000);
    for i in 0..n {
        assert!(store.insert(((i % 8) << 61) | i, i));
    }
    // Erase the odd half, then reinsert shifted: every reinsert allocates
    // while the free list is (deterministically, half the time) "empty".
    for i in (1..n).step_by(2) {
        assert!(store.erase(((i % 8) << 61) | i));
    }
    for i in (1..n).step_by(2) {
        assert!(store.insert(((i % 8) << 61) | i, i + 1));
    }
    assert_eq!(store.len(), n, "churn preserves cardinality");
    for i in 0..n {
        let want = if i % 2 == 1 { i + 1 } else { i };
        assert_eq!(store.get(((i % 8) << 61) | i), Some(want), "key {i}");
    }
}

/// The full engine (`run_with_opts`, Delegated mode) survives an injected
/// owner kill: the run completes with every op accounted for, records the
/// death and a measured recovery, and lands on the same final state as an
/// unfaulted Direct-mode run of the identical spec (HASH mix: membership
/// is order-independent under takeover).
#[test]
fn chaos_engine_run_with_owner_kill() {
    let ops = scaled_ops(200_000);
    let topo = Topology::virtual_grid(2, 2);
    let spec = WorkloadSpec::new("chaos-it", ops, OpMix::HASH, (ops / 2).max(1 << 14));
    let router = KeyRouter::Native;
    let mk_store = |threads| {
        Arc::new(ShardedStore::new(
            StoreKind::DetSkiplistLf,
            8,
            (ops as usize / 4).max(1 << 14),
            topo.clone(),
            threads,
        ))
    };
    let oracle = mk_store(4);
    run_with_opts(&oracle, &spec, 4, &router, 0x17, RunOptions::default());
    let guard = FaultPlan::new(0x17_17).kill_nth("fabric.owner.kill", 40).install();
    let store = mk_store(4);
    let m = run_with_opts(
        &store,
        &spec,
        4,
        &router,
        0x17,
        RunOptions {
            mode: ExecMode::Delegated,
            op_timeout: Some(Duration::from_secs(10)),
            ..RunOptions::default()
        },
    );
    drop(guard);
    assert_eq!(m.ops(), ops, "zero lost completions: every op drains exactly once");
    let f = &m.fabric;
    assert_eq!(f.submitted, f.executed + f.errored, "quiescence balance");
    assert_eq!(f.errored, 0, "a clean kill loses nothing");
    assert!(f.owner_deaths >= 1, "the injected kill must be recorded: {f:?}");
    assert!(f.recovery_ns > 0, "takeover must be timestamped");
    assert_eq!(
        store.range(0, u64::MAX),
        oracle.range(0, u64::MAX),
        "post-recovery state agrees with the unfaulted Direct run"
    );
}

/// Satellite 6 regression: a *caller-side* panic (a test assertion, a bug
/// in workload code — anything outside shard execution) must not poison
/// the whole fabric. The unwinding caller publishes its done-mark, the
/// fabric stays healthy, and a fresh caller keeps working.
#[test]
fn chaos_caller_panic_does_not_poison_fabric() {
    let ((), _store, fabric) = with_owner_pool(
        StoreKind::DetSkiplistLf,
        4,
        Topology::virtual_grid(2, 2),
        8,
        |caller_id, fabric, store| {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut caller = fabric.caller(caller_id, None);
                for i in 0..64u64 {
                    caller.delegate(DelegatedOp::Insert { key: i, value: i }, store);
                }
                caller.flush(store);
                panic!("caller-side assertion failure");
            }));
            assert!(r.is_err(), "the panic must reach us");
            assert!(!fabric.is_poisoned(), "caller panics must not poison the fabric");
            // The fabric is still fully operational for everyone else.
            fabric.set_op_timeout(Some(Duration::from_secs(30)));
            let mut caller = fabric.caller(caller_id + 1, None);
            let got = caller
                .call(DelegatedOp::Insert { key: 1 << 61 | 9, value: 9 }, store)
                .expect("a fresh caller still works");
            assert_eq!(got, OpResult::Applied(true));
            caller.finish(store);
            quiesce(fabric, "caller-panic");
        },
    );
    let st = fabric.stats();
    assert_eq!(st.owner_deaths, 0, "no owner was involved in the caller's panic");
    assert_eq!(st.errored, 0);
    assert_eq!(st.executed, st.submitted, "the panicking caller's flushed ops still ran");
}
