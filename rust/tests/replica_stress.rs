//! Replicated-index-plane property tests (run in CI as the release
//! replica stress step: `CDSKL_SCALE=10 cargo test --release -q replica_`).
//!
//! The per-node index replicas are *hints*: a replicated read must agree
//! exactly with the shared index no matter how stale its replica is —
//! staleness may cost a bounded local repair walk or a fallback, never a
//! wrong answer (DESIGN.md §Replicated-index-layers). These tests starve
//! the maintenance tick on purpose, churn the terminal list underneath
//! live replicas, and check every answer against a `BTreeMap` oracle;
//! then they rebuild at quiescence and assert the replicas converge
//! (reads stop falling back, `check_invariants` proves entry-for-entry
//! agreement with the shared terminal list).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cdskl::coordinator::{run_with_opts, ExecMode, RunOptions, ShardedStore, StoreKind};
use cdskl::numa::Topology;
use cdskl::runtime::KeyRouter;
use cdskl::skiplist::{DetSkiplist, FindMode};
use cdskl::util::rng::Rng;
use cdskl::workload::{OpMix, WorkloadSpec};

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness
/// (CI runs release with CDSKL_SCALE=10 for a deeper soak).
fn scaled_ops(base: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (base / scale.max(1)).max(2_000)
}

/// Deterministic value for a key — concurrent tests can validate any
/// observed `Some(v)` without tracking interleavings.
fn val(k: u64) -> u64 {
    k.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(k)
}

/// Every replicated answer must match the oracle even though the replica
/// is never ticked after its initial build — writes below make it
/// arbitrarily stale, and the live landing validation plus the repair
/// walks (walk-right / left-step / parent retry / fallback) must absorb
/// every stale route.
#[test]
fn replica_matches_oracle_when_forced_stale() {
    let ops = scaled_ops(200_000);
    let sl = DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16);
    let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
    for i in 0..2_000u64 {
        let k = i * 7 + 3;
        sl.insert(k, val(k));
        oracle.insert(k, val(k));
    }
    sl.enable_replicas(&Topology::virtual_grid(4, 4), 16);
    let mut rng = Rng::new(0x5E9A);
    for i in 0..ops {
        // tight key space: every chunk sees splits, merges and boundary
        // raises while the replica keeps routing through the old layout
        let k = rng.below(16_384) + 1;
        match rng.below(10) {
            0..=2 => {
                let fresh = !oracle.contains_key(&k);
                if fresh {
                    oracle.insert(k, val(k));
                }
                assert_eq!(sl.insert(k, val(k)), fresh, "insert({k}) disagreed at op {i}");
            }
            3..=4 => {
                assert_eq!(sl.erase(k), oracle.remove(&k).is_some(), "erase({k}) at op {i}");
            }
            5..=8 => {
                let (v, _fell_back) = sl.get_replicated(k);
                assert_eq!(v, oracle.get(&k).copied(), "get_replicated({k}) at op {i}");
            }
            _ => {
                let lo = rng.below(16_384);
                let hi = lo + rng.below(256);
                let want: Vec<(u64, u64)> =
                    oracle.range(lo..=hi).map(|(&k, &v)| (k, v)).collect();
                let (rows, _fell_back) = sl.range_replicated(lo, hi);
                assert_eq!(rows, want, "range_replicated({lo},{hi}) at op {i}");
            }
        }
    }
    let st = sl.replica_stats();
    assert!(st.lookups > 0, "the replica plane must have served reads");
    assert!(st.records_published > 0, "writes must publish invalidations");
    assert_eq!(st.records_consumed, 0, "tick starved: nothing may be consumed");
    assert_eq!(st.remote_index_derefs, 0, "reads route through the local replica");
    sl.check_invariants().expect("stale replicas must still pass the weak invariants");
}

/// Descent-miss repair convergence: flood the list with keys the replica
/// has never seen (every lookup of them degrades or falls back), then
/// force a quiescent rebuild — after it, reads of every resident key must
/// resolve on-replica without a single new fallback, and the strong
/// `check_invariants` agreement (exact replicas mirror the terminal list
/// entry-for-entry) must hold.
#[test]
fn replica_repair_converges_after_rebuild() {
    let n = scaled_ops(60_000);
    let sl = DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16);
    for i in 0..1_000u64 {
        sl.insert(i * 31 + 5, val(i * 31 + 5));
    }
    sl.enable_replicas(&Topology::virtual_grid(2, 4), 8);
    // grow the list far past the replicated snapshot — no ticks
    for i in 0..n {
        let k = 1_000 * 31 + 7 + i * 3;
        sl.insert(k, val(k));
        assert_eq!(sl.get_replicated(k).0, Some(val(k)), "stale read of fresh key {k}");
    }
    sl.replica_rebuild_all();
    sl.check_invariants().expect("exact replicas must mirror the terminal list");
    let before = sl.replica_stats();
    for i in 0..n {
        let k = 1_000 * 31 + 7 + i * 3;
        let (v, fell_back) = sl.get_replicated(k);
        assert_eq!(v, Some(val(k)));
        assert!(!fell_back, "post-rebuild read of {k} must resolve on-replica");
    }
    let after = sl.replica_stats();
    assert_eq!(after.fallbacks, before.fallbacks, "rebuilt replicas must stop falling back");
    assert_eq!(after.lookups - before.lookups, n, "every probe went through the replica");
}

/// Concurrent churn: writers mutate disjoint key stripes (each tracking
/// its own oracle) while readers hammer `get_replicated`/`range_replicated`
/// and maintenance ticks race the writers' invalidation stream. Any
/// observed value must be the key's deterministic value; afterwards the
/// quiescent state must agree with the merged oracles and pass the full
/// invariant check.
#[test]
fn replica_concurrent_churn_stays_safe() {
    const KEYS: u64 = 8_192;
    let ops = scaled_ops(160_000);
    let sl = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 16));
    for k in 1..=KEYS {
        sl.insert(k, val(k));
    }
    sl.enable_replicas(&Topology::virtual_grid(2, 2), 4);
    let done = Arc::new(AtomicBool::new(false));
    let writers: Vec<_> = (0..2u64)
        .map(|t| {
            let sl = Arc::clone(&sl);
            std::thread::spawn(move || {
                let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
                for k in (1..=KEYS).filter(|k| k % 2 == (t + 1) % 2) {
                    oracle.insert(k, val(k));
                }
                let mut rng = Rng::new(0xC0FE ^ t);
                for i in 0..ops {
                    // stripe-local key: writers never contend on a key, so
                    // each oracle is exact for its half of the space
                    let k = rng.below(KEYS / 2) * 2 + t + 1;
                    let k = if k > KEYS { t + 1 } else { k };
                    if rng.below(2) == 0 {
                        let fresh = !oracle.contains_key(&k);
                        if fresh {
                            oracle.insert(k, val(k));
                        }
                        assert_eq!(sl.insert(k, val(k)), fresh, "w{t}: insert({k}) at {i}");
                    } else {
                        assert_eq!(sl.erase(k), oracle.remove(&k).is_some(), "w{t}: erase({k})");
                    }
                    if i % 64 == 0 {
                        sl.replica_tick(); // patch path racing the churn
                    }
                }
                oracle
            })
        })
        .collect();
    let readers: Vec<_> = (0..2u64)
        .map(|t| {
            let sl = Arc::clone(&sl);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xFEED ^ t);
                let mut seen = 0u64;
                while !done.load(Ordering::Acquire) {
                    let k = rng.below(KEYS) + 1;
                    if let (Some(v), _) = sl.get_replicated(k) {
                        assert_eq!(v, val(k), "reader {t}: wrong value for live key {k}");
                        seen += 1;
                    }
                    let lo = rng.below(KEYS);
                    let (rows, _) = sl.range_replicated(lo, lo + 64);
                    let mut prev = 0u64;
                    for &(k, v) in &rows {
                        assert!(k >= lo && k <= lo + 64 && k > prev, "reader {t}: row order");
                        assert_eq!(v, val(k), "reader {t}: wrong value in range row {k}");
                        prev = k;
                    }
                }
                seen
            })
        })
        .collect();
    let mut oracle = BTreeMap::new();
    for w in writers {
        oracle.append(&mut w.join().unwrap());
    }
    done.store(true, Ordering::Release);
    let mut seen = 0;
    for r in readers {
        seen += r.join().unwrap();
    }
    assert!(seen > 0, "readers must have observed live keys");
    // quiescence: converge the replicas, then demand exact agreement
    sl.replica_rebuild_all();
    sl.check_invariants().expect("post-churn invariants (incl. replica agreement)");
    for (&k, &v) in &oracle {
        assert_eq!(sl.get_replicated(k).0, Some(v), "final get_replicated({k})");
    }
    let want: Vec<(u64, u64)> = oracle.iter().map(|(&k, &v)| (k, v)).collect();
    assert_eq!(sl.range_replicated(0, u64::MAX - 2).0, want, "final replicated sweep");
    assert_eq!(sl.len(), want.len() as u64);
    assert_eq!(sl.replica_stats().remote_index_derefs, 0);
}

/// Sharded-store surface: the same oracle discipline through
/// [`ShardedStore::get_replicated`]/[`range_replicated`] with periodic
/// whole-store ticks (the engine's cadence), across shard boundaries.
#[test]
fn replica_sharded_store_matches_oracle() {
    let ops = scaled_ops(120_000);
    let topo = Topology::virtual_grid(2, 4);
    let store = ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 14, topo, 8);
    let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
    let mut rng = Rng::new(0x5AAD);
    for _ in 0..2_000u64 {
        // spread the prefill across all 8 prefix segments (shards)
        let k = (rng.below(8) << 61) | (rng.below(1 << 14) + 1);
        store.insert(k, val(k));
        oracle.insert(k, val(k));
    }
    store.enable_replication();
    assert!(store.replication_enabled());
    for i in 0..ops {
        let k = (rng.below(8) << 61) | (rng.below(1 << 14) + 1);
        match rng.below(10) {
            0..=2 => {
                let fresh = !oracle.contains_key(&k);
                if fresh {
                    oracle.insert(k, val(k));
                }
                assert_eq!(store.insert(k, val(k)), fresh, "insert({k:#x}) at op {i}");
            }
            3..=4 => {
                assert_eq!(store.erase(k), oracle.remove(&k).is_some(), "erase({k:#x})");
            }
            5..=8 => {
                assert_eq!(
                    store.get_replicated(0, k),
                    oracle.get(&k).copied(),
                    "get_replicated({k:#x}) at op {i}"
                );
            }
            _ => {
                // cross-shard window: spans the segment boundary whenever
                // lo lands near the top of a segment
                let lo = (rng.below(8) << 61) | ((1u64 << 61) - rng.below(512) - 1);
                let hi = lo.saturating_add(1 << 60);
                let want: Vec<(u64, u64)> =
                    oracle.range(lo..=hi).map(|(&k, &v)| (k, v)).collect();
                assert_eq!(store.range_replicated(0, lo, hi), want, "range({lo:#x})");
            }
        }
        if i % 128 == 0 {
            store.replica_tick();
        }
    }
    store.replica_rebuild();
    let want: Vec<(u64, u64)> = oracle.iter().map(|(&k, &v)| (k, v)).collect();
    assert_eq!(store.range_replicated(0, 0, u64::MAX - 2), want, "final sweep");
    let rs = store.replica_stats();
    assert!(rs.lookups > 0 && rs.ticks > 0);
    assert_eq!(rs.remote_index_derefs, 0);
}

/// Engine end-to-end with the maintenance tick disabled
/// (`replica_tick_every: 0`): replicas stay as stale as they can possibly
/// get for the whole drain, yet a Replicated run must produce exactly the
/// same answers as a Direct run of the same seeded workload.
#[test]
fn replica_engine_forced_stale_matches_direct() {
    let ops = scaled_ops(120_000);
    let topo = Topology::virtual_grid(2, 2);
    let router = KeyRouter::Native;
    let spec = WorkloadSpec::new("replica-stale", ops, OpMix::READ50, (ops / 2).max(1 << 12))
        .with_range_window(64);
    let mk = || Arc::new(ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 14, topo.clone(), 4));
    let direct = mk();
    let md = run_with_opts(
        &direct,
        &spec,
        4,
        &router,
        0x51A1E,
        RunOptions { mode: ExecMode::Direct, ..Default::default() },
    );
    let repl = mk();
    let mr = run_with_opts(
        &repl,
        &spec,
        4,
        &router,
        0x51A1E,
        RunOptions { mode: ExecMode::Replicated, replica_tick_every: 0, ..Default::default() },
    );
    assert_eq!(md.final_len, mr.final_len, "final length disagreed");
    assert_eq!(md.found, mr.found, "find hits disagreed");
    assert_eq!(
        direct.range(0, u64::MAX - 2),
        repl.range(0, u64::MAX - 2),
        "final contents disagreed"
    );
    let rs = mr.replica;
    assert!(rs.lookups > 0, "drain reads must route through the replica plane");
    assert!(rs.records_published > 0, "drain writes must publish invalidations");
    assert_eq!(rs.records_consumed, 0, "tick_every=0 must never sync a replica");
    assert_eq!(rs.remote_index_derefs, 0, "replicated reads stay node-local");
}

/// Satellite: the finger cache is mode-aware. Replica descents never
/// consult fingers, and in Replicated mode the engine turns the cache off
/// entirely — so with the cache disabled at the boundary (as the engine
/// does), an arbitrary replicated read/tick mix must leave
/// `finger_attempts` untouched, including on the fallback path.
#[test]
fn replica_reads_bypass_finger_cache() {
    let sl = DetSkiplist::with_capacity(FindMode::LockFree, 1 << 14);
    for i in 0..2_000u64 {
        sl.insert(i * 3 + 1, val(i * 3 + 1));
    }
    assert!(sl.finger_cache_enabled(), "fingers default on");
    // the engine's Replicated-mode boundary: fingers off, replicas on
    sl.set_finger_cache(false);
    sl.enable_replicas(&Topology::virtual_grid(2, 2), 4);
    let base = sl.stats().finger_attempts;
    for i in 0..2_000u64 {
        let k = i * 3 + 1;
        assert_eq!(sl.get_replicated(k).0, Some(val(k)));
        let _ = sl.get_replicated(k + 1); // absent key: may take the fallback path
        let _ = sl.range_replicated(k, k + 64);
        sl.insert(6_001 + i * 2, 0); // keep writes flowing through the hooks
        sl.replica_tick();
    }
    assert_eq!(
        sl.stats().finger_attempts,
        base,
        "replicated reads and their fallbacks must never consult the finger cache"
    );
}
