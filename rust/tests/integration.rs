//! Cross-module integration: coordinator + router + stores + workload,
//! and end-to-end conservation properties.

use std::sync::Arc;

use cdskl::coordinator::{run_workload, ShardedStore, StoreKind};
use cdskl::numa::{Topology, LATENCY};
use cdskl::runtime::KeyRouter;
use cdskl::workload::{OpKind, OpMix, WorkloadSpec};

fn milan2() -> Topology {
    Topology::virtual_grid(2, 2)
}

#[test]
fn every_store_kind_completes_a_routed_workload() {
    for kind in [
        StoreKind::DetSkiplistLf,
        StoreKind::DetSkiplistRwl,
        StoreKind::RandomSkiplist,
        StoreKind::HashFixed,
        StoreKind::HashTwoLevel,
        StoreKind::HashSpo,
        StoreKind::HashTwoLevelSpo,
        StoreKind::HashTbbLike,
    ] {
        let store = Arc::new(ShardedStore::new(kind, 8, 1 << 14, milan2(), 4));
        let spec = WorkloadSpec::new("it", 8_000, OpMix::W2, 1 << 12);
        let m = run_workload(&store, &spec, 4, &KeyRouter::Native, 5);
        assert_eq!(m.ops(), 8_000, "{kind:?}: op conservation");
        assert_eq!(m.remote_accesses, 0, "{kind:?}: NUMA-local routing");
        assert!(m.final_len <= m.inserts, "{kind:?}");
    }
}

#[test]
fn op_transport_roundtrip_is_lossless() {
    let spec = WorkloadSpec::new("t", 0, OpMix::W2, 1 << 20);
    let batch = cdskl::runtime::native_route(7, 8192, 10_000);
    let (mut i, mut f, mut e) = (0, 0, 0);
    for &raw in &batch.keys {
        let word = spec.encode(raw, 0);
        let (op, key) = WorkloadSpec::decode(word);
        assert_eq!(key, spec.fold_key(raw), "key survives transport");
        assert_eq!(key >> 61, raw >> 61, "shard bits survive");
        match op {
            OpKind::Insert => i += 1,
            OpKind::Find => f += 1,
            OpKind::Erase => e += 1,
            OpKind::Range => unreachable!("W2 has no range ops"),
        }
    }
    assert!(i > 800 && i < 1_200, "inserts {i}");
    assert!(f > 8_500, "finds {f}");
    assert!(e > 2 && e < 60, "erases {e}");
}

#[test]
fn finds_hit_inserted_population() {
    // With a bounded key space, a decent fraction of finds must hit keys
    // that inserts created (regression test for op/key correlation).
    let store = Arc::new(ShardedStore::new(StoreKind::HashTwoLevelSpo, 8, 1 << 14, milan2(), 4));
    let spec = WorkloadSpec::new("hits", 40_000, OpMix::HASH, 1 << 10);
    let m = run_workload(&store, &spec, 4, &KeyRouter::Native, 11);
    assert!(
        m.found as f64 > m.finds as f64 * 0.5,
        "with 2^10 keyspace and 50% inserts most finds must hit: {}/{}",
        m.found,
        m.finds
    );
}

#[test]
fn latency_injection_slows_remote_heavy_runs() {
    // Force remote accesses by *mis-homing*: 1 thread on a 2-node topology
    // means every odd shard is remote-ish... with 1 thread nodes_in_use=1,
    // everything is local. Instead drive the store directly from an
    // unpinned accessor against far shards.
    let store = ShardedStore::new(StoreKind::HashFixed, 8, 1 << 12, Topology::milan_virtual(), 128);
    // shard 7 homes on node 7; "thread 0" sits on node 0 => remote
    LATENCY.enable(20_000); // 20us per remote access
    let t0 = std::time::Instant::now();
    for i in 0..50u64 {
        let key = 7u64 << 61 | i;
        store.account(0, key);
        store.insert(key, i);
    }
    let slow = t0.elapsed();
    LATENCY.disable();
    let t0 = std::time::Instant::now();
    for i in 100..150u64 {
        let key = 7u64 << 61 | i;
        store.account(0, key);
        store.insert(key, i);
    }
    let fast = t0.elapsed();
    assert!(slow > fast * 3, "injection must dominate: slow={slow:?} fast={fast:?}");
    let (_, remote) = store.locality.snapshot();
    assert_eq!(remote, 100);
}

#[test]
fn eq6_eq7_hierarchy_matches_paper_example() {
    // Paper's worked example: T=32, n_cpu=16 -> n_u=2; even skiplists
    // serviced by node-0 threads, odd by node-1 threads.
    let topo = Topology::milan_virtual();
    let store = ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 10, topo, 32);
    for shard in 0..8 {
        assert_eq!(store.home_node(shard), shard % 2);
    }
}

#[test]
fn sharded_range_partition_is_disjoint() {
    // Keys with distinct MSBs land in distinct shards; each shard only
    // holds its own keyspace slice.
    let store = ShardedStore::new(StoreKind::DetSkiplistLf, 8, 1 << 12, milan2(), 4);
    for shard in 0..8u64 {
        for i in 0..100u64 {
            store.insert(shard << 61 | i, i);
        }
    }
    assert_eq!(store.len(), 800);
    for shard in 0..8u64 {
        for i in 0..100u64 {
            assert_eq!(store.get(shard << 61 | i), Some(i));
        }
    }
}
