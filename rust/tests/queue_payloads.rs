//! Generic-payload property tests for every queue implementation: a
//! non-`Copy` payload (a `Box` plus a live-object counter) must be dropped
//! **exactly once** across any push/pop/queue-drop interleaving — no leak
//! (drop never runs), no double free (drop runs twice), no value invented
//! or lost in transit. The live counter is the oracle: it must equal the
//! number of values currently owned by the queue at every step, and zero
//! once popped values and the dropped queue are gone.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use cdskl::queue::{ConcurrentQueue, LfQueue, MsQueue, MutexQueue, TbbLikeQueue};
use cdskl::util::miniprop::forall_vec_u64;

/// A non-`Copy` payload: heap value + live-object accounting.
struct Payload {
    v: Box<u64>,
    live: Arc<AtomicI64>,
}

impl Payload {
    fn new(v: u64, live: &Arc<AtomicI64>) -> Payload {
        live.fetch_add(1, Ordering::SeqCst);
        Payload { v: Box::new(v), live: live.clone() }
    }
}

impl Drop for Payload {
    fn drop(&mut self) {
        self.live.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Single-threaded interleavings driven by a random op vector (even value =
/// push, odd = pop), with a VecDeque model checking FIFO content and the
/// live counter checking ownership; the queue is dropped with residue
/// still enqueued, which must drop exactly the residue.
fn drop_exactly_once_property<Q, F>(make: F, seed: u64)
where
    Q: ConcurrentQueue<Payload>,
    F: Fn() -> Q,
{
    forall_vec_u64(seed, 40, 300, 1 << 20, |ops| {
        let live = Arc::new(AtomicI64::new(0));
        let mut model: VecDeque<u64> = VecDeque::new();
        {
            let q = make();
            for &o in ops {
                if o % 2 == 0 {
                    q.push(Payload::new(o, &live));
                    model.push_back(o);
                } else {
                    let got = q.pop().map(|p| *p.v); // popped Payload drops here
                    let want = model.pop_front();
                    if got != want {
                        return Err(format!("pop: got {got:?} want {want:?}"));
                    }
                }
                let inside = live.load(Ordering::SeqCst);
                if inside != model.len() as i64 {
                    return Err(format!(
                        "live {inside} != enqueued {} after op {o}",
                        model.len()
                    ));
                }
            }
            // q drops here with model.len() values still enqueued
        }
        let after = live.load(Ordering::SeqCst);
        if after != 0 {
            return Err(format!(
                "queue drop must free the {} residual values exactly once, {after} live remain",
                model.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn queue_payload_drop_exactly_once_lfqueue() {
    // tiny blocks force block hand-over and recycling under payloads; the
    // directory still fits an all-pushes-first interleaving (300 ops / 4
    // slots < 128 blocks), since a single-threaded run has no consumer to
    // unblock a full push
    drop_exactly_once_property(|| LfQueue::<Payload>::with_config(4, 128, true), 0x71);
}

#[test]
fn queue_payload_drop_exactly_once_tbb_like() {
    drop_exactly_once_property(|| TbbLikeQueue::<Payload>::with_config(4, 1 << 10), 0x72);
}

#[test]
fn queue_payload_drop_exactly_once_ms_queue() {
    drop_exactly_once_property(|| MsQueue::<Payload>::with_block_size(4), 0x73);
}

#[test]
fn queue_payload_drop_exactly_once_mutex_queue() {
    drop_exactly_once_property(MutexQueue::<Payload>::new, 0x74);
}

/// MPMC stress: concurrent producers/consumers exercise the contended
/// paths (killed slots, block recycling, MS tag retries) that
/// single-threaded interleavings cannot reach. Every pushed value must be
/// popped exactly once (drain completes) and every payload dropped exactly
/// once overall.
fn mpmc_drop_exactly_once<Q, F>(make: F)
where
    Q: ConcurrentQueue<Payload> + 'static,
    F: Fn() -> Q,
{
    let q = Arc::new(make());
    let live = Arc::new(AtomicI64::new(0));
    let producers = 3u64;
    let consumers = 3;
    let per = 4_000u64;
    let popped = Arc::new(AtomicU64::new(0));
    let seen = Arc::new(Mutex::new(std::collections::HashSet::new()));
    std::thread::scope(|scope| {
        for p in 0..producers {
            let q = q.clone();
            let live = live.clone();
            scope.spawn(move || {
                for i in 0..per {
                    q.push(Payload::new(p << 32 | i, &live));
                }
            });
        }
        for _ in 0..consumers {
            let q = q.clone();
            let popped = popped.clone();
            let seen = seen.clone();
            scope.spawn(move || {
                let mut local = Vec::new();
                while popped.load(Ordering::Relaxed) < producers * per {
                    match q.pop() {
                        Some(v) => {
                            popped.fetch_add(1, Ordering::Relaxed);
                            local.push(*v.v); // payload drops, value kept
                        }
                        None => std::thread::yield_now(),
                    }
                }
                let mut s = seen.lock().unwrap();
                for v in local {
                    assert!(s.insert(v), "value {v:#x} popped twice");
                }
            });
        }
    });
    assert_eq!(popped.load(Ordering::SeqCst), producers * per);
    assert_eq!(seen.lock().unwrap().len() as u64, producers * per, "every value exactly once");
    assert_eq!(live.load(Ordering::SeqCst), 0, "every payload dropped exactly once");
    drop(q);
    assert_eq!(live.load(Ordering::SeqCst), 0);
}

#[test]
fn queue_payload_mpmc_drop_exactly_once_lfqueue() {
    // small blocks => frequent hand-over, kills and recycling under load
    mpmc_drop_exactly_once(|| LfQueue::<Payload>::with_config(16, 1 << 10, true));
}

#[test]
fn queue_payload_mpmc_drop_exactly_once_ms_queue() {
    mpmc_drop_exactly_once(|| MsQueue::<Payload>::with_block_size(16));
}

/// The same exactly-once-drop invariants under *injected* faults
/// (`--features failpoints`): spurious `try_push` rejections must hand the
/// payload back intact, forced slot kills must drive the pusher's
/// take-back path, and a widened `taken` rendezvous window must still hand
/// each MS node's value to exactly one consumer. Named `chaos_` so the CI
/// chaos stress step picks them up.
#[cfg(feature = "failpoints")]
mod chaos {
    use super::*;
    use cdskl::util::fail::FaultPlan;

    #[test]
    fn chaos_queue_payload_spurious_try_push_returns_value_intact() {
        let _g = FaultPlan::new(0xF001).fail_nth("queue.try_push", 1).install();
        let live = Arc::new(AtomicI64::new(0));
        let q: LfQueue<Payload> = LfQueue::with_config(4, 8, true);
        let p = Payload::new(42, &live);
        let p = match q.try_push(p) {
            Err(p) => p,
            Ok(()) => panic!("first try_push must be rejected by the plan"),
        };
        assert_eq!(live.load(Ordering::SeqCst), 1, "rejected payload stays alive");
        assert_eq!(*p.v, 42, "rejected payload comes back intact");
        q.try_push(p).expect("second attempt proceeds");
        assert_eq!(*q.pop().expect("value round-trips").v, 42);
        assert_eq!(live.load(Ordering::SeqCst), 0, "dropped exactly once");
    }

    #[test]
    fn chaos_queue_payload_forced_slot_kills_drop_exactly_once() {
        // Skip the pop grace period on a quarter of claimed slots: the
        // EMPTY->KILLED race and the pusher's take-back run constantly.
        let _g = FaultPlan::new(0xF002).fail_prob("queue.pop.kill", 1, 4).install();
        mpmc_drop_exactly_once(|| LfQueue::<Payload>::with_config(16, 1 << 10, true));
    }

    #[test]
    fn chaos_queue_payload_spurious_full_storm_mpmc() {
        // try_push storms only reject; the blocking push used by the MPMC
        // harness must be unaffected, and a try_push retry loop completes.
        let _g = FaultPlan::new(0xF003).fail_prob("queue.try_push", 1, 4).install();
        let live = Arc::new(AtomicI64::new(0));
        let q: LfQueue<Payload> = LfQueue::with_config(16, 1 << 10, true);
        for i in 0..500u64 {
            let mut p = Payload::new(i, &live);
            loop {
                match q.try_push(p) {
                    Ok(()) => break,
                    Err(back) => p = back, // spurious full: retry with the same value
                }
            }
        }
        for i in 0..500u64 {
            assert_eq!(*q.pop().expect("FIFO intact under storm").v, i);
        }
        assert_eq!(live.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn chaos_queue_payload_msq_taken_delay_rendezvous() {
        // Stretch the value-read -> `taken`-publish window so the
        // recycler's rendezvous spin is exercised under real contention.
        let _g =
            FaultPlan::new(0xF004).delay_prob("msq.taken.delay", 1, 16, 50_000).install();
        mpmc_drop_exactly_once(|| MsQueue::<Payload>::with_block_size(16));
    }
}
