//! Ordered-map API across the stack: cross-shard range scans under
//! concurrency, routed batch ops, the mixed point/range workload through
//! the coordinator engine, and end-to-end stats observability.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cdskl::coordinator::{bulk_load, run_workload, ShardedStore, StoreKind};
use cdskl::numa::Topology;
use cdskl::runtime::KeyRouter;
use cdskl::workload::{OpMix, WorkloadSpec};

/// Writer keys set bit 20; committed keys keep it clear — the two
/// populations never collide.
const WRITER_BIT: u64 = 1 << 20;

/// Multi-threaded stress (4 writers + 3 scanners over 8 shards): every
/// cross-shard range result must be sorted, duplicate-free, and contain
/// every key committed before the scan started.
#[test]
fn cross_shard_range_sorted_and_complete_under_writers() {
    let store = Arc::new(ShardedStore::new(
        StoreKind::DetSkiplistLf,
        8,
        1 << 16,
        Topology::milan_virtual(),
        8,
    ));
    // committed population: 500 keys in each of the 8 prefixes, loaded
    // through the routed batch path before any scanner starts
    let committed: Vec<(u64, u64)> = (0..8u64)
        .flat_map(|p| (0..500u64).map(move |i| (p << 61 | i * 3, p)))
        .collect();
    assert_eq!(store.insert_batch(&committed), 4_000);
    let committed_keys: Vec<u64> = committed.iter().map(|&(k, _)| k).collect();

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        // 4 writers keep mutating a disjoint population while scans run
        let mut writers = Vec::new();
        for t in 0..4u64 {
            let store = store.clone();
            writers.push(scope.spawn(move || {
                for i in 0..2_000u64 {
                    let key = (i % 8) << 61 | WRITER_BIT | t << 21 | i;
                    store.insert(key, t);
                    if i % 3 == 0 {
                        store.erase(key);
                    }
                }
            }));
        }
        // 3 scanners: full scans + windowed scans, validated on every pass
        for s in 0..3u64 {
            let store = store.clone();
            let stop = stop.clone();
            let committed_keys = committed_keys.clone();
            scope.spawn(move || {
                let mut passes = 0u64;
                loop {
                    let done = stop.load(Ordering::Relaxed);
                    let rows = store.range(0, u64::MAX - 2);
                    assert!(
                        rows.windows(2).all(|w| w[0].0 < w[1].0),
                        "scanner {s}: cross-shard scan must be sorted and duplicate-free"
                    );
                    let keys: BTreeSet<u64> = rows.iter().map(|&(k, _)| k).collect();
                    for &k in &committed_keys {
                        assert!(keys.contains(&k), "scanner {s}: committed key {k:#x} missing");
                    }
                    // windowed scan inside one prefix
                    let p = (passes % 8) << 61;
                    let w = store.range(p, p | 300);
                    assert!(w.windows(2).all(|x| x[0].0 < x[1].0));
                    assert!(w.iter().all(|&(k, _)| k >= p && k <= (p | 300)));
                    passes += 1;
                    if done {
                        break; // one full validated pass after writers stop
                    }
                }
                assert!(passes > 0);
            });
        }
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
    });

    // quiescent: committed keys all present with their values
    for &(k, v) in &committed {
        assert_eq!(store.get(k), Some(v));
    }
}

/// The same stress shape on the randomized skiplist backend (its range is
/// a separate native implementation).
#[test]
fn cross_shard_range_on_random_skiplist_backend() {
    let store = Arc::new(ShardedStore::new(
        StoreKind::RandomSkiplist,
        4,
        1 << 16,
        Topology::virtual_grid(2, 2),
        4,
    ));
    let committed: Vec<(u64, u64)> = (0..8u64)
        .flat_map(|p| (0..250u64).map(move |i| (p << 61 | i * 2, i)))
        .collect();
    assert_eq!(store.insert_batch(&committed), 2_000);
    std::thread::scope(|scope| {
        for t in 0..2u64 {
            let store = store.clone();
            scope.spawn(move || {
                for i in 0..1_000u64 {
                    store.insert((i % 8) << 61 | WRITER_BIT | t << 21 | i, i);
                }
            });
        }
        for _ in 0..2 {
            let store = store.clone();
            let committed = committed.clone();
            scope.spawn(move || {
                for _ in 0..20 {
                    let rows = store.range(0, u64::MAX - 2);
                    assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "sorted, dup-free");
                    let keys: BTreeSet<u64> = rows.iter().map(|&(k, _)| k).collect();
                    for &(k, _) in &committed {
                        assert!(keys.contains(&k), "committed key {k:#x} missing");
                    }
                }
            });
        }
    });
}

/// The mixed point/range workload drains through the queue fabric with op
/// conservation and NUMA-local routing intact.
#[test]
fn engine_mixed_range_workload_conserves_ops() {
    let store = Arc::new(ShardedStore::new(
        StoreKind::DetSkiplistLf,
        8,
        1 << 16,
        Topology::virtual_grid(2, 2),
        4,
    ));
    let spec = WorkloadSpec::new("range-it", 24_000, OpMix::RANGE, 1 << 12).with_range_window(32);
    let m = run_workload(&store, &spec, 4, &KeyRouter::Native, 77);
    assert_eq!(m.ops(), 24_000, "inserts + finds + erases + ranges must conserve");
    assert!(m.ranges > 3_600 && m.ranges < 6_000, "~20% range ops, got {}", m.ranges);
    assert!(m.range_rows > 0, "bounded key space: scans must return rows");
    assert_eq!(m.remote_accesses, 0, "routing must stay NUMA-local");
}

/// Stats flow end-to-end: per-shard skiplist counters aggregate on the
/// sharded store, and a range-heavy read phase moves only the find-side
/// counters — write_retries must not inflate.
#[test]
fn range_heavy_phase_records_no_write_retries() {
    let store = Arc::new(ShardedStore::new(
        StoreKind::DetSkiplistLf,
        4,
        1 << 16,
        Topology::virtual_grid(2, 2),
        4,
    ));
    let items: Vec<(u64, u64)> = (0..8_000u64).map(|i| ((i % 8) << 61 | i, i)).collect();
    let (_, loaded) = bulk_load(&store, &items, 4);
    assert_eq!(loaded, 8_000);
    let before = store.stats();
    assert!(before.splits > 0, "load phase must have split nodes");

    // range-heavy phase: concurrent scanners, zero writers
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let store = store.clone();
            scope.spawn(move || {
                for i in 0..200u64 {
                    let lo = ((i + t) % 8) << 61 | i * 7;
                    let rows = store.range(lo, lo + 128);
                    assert!(rows.windows(2).all(|w| w[0].0 < w[1].0));
                }
            });
        }
    });
    let after = store.stats();
    assert_eq!(
        after.write_retries, before.write_retries,
        "a pure range phase must not inflate write_retries"
    );
    assert!(
        after.find_retries >= before.find_retries,
        "find-side counters only ever grow"
    );
    assert_eq!(after.splits, before.splits, "no structural writes during scans");
}

/// Routed batch erase across shards composes with range: erased windows
/// disappear from cross-shard scans.
#[test]
fn batch_erase_composes_with_cross_shard_range() {
    let store = Arc::new(ShardedStore::new(
        StoreKind::HashTwoLevelSpo,
        8,
        1 << 14,
        Topology::milan_virtual(),
        8,
    ));
    let items: Vec<(u64, u64)> = (0..8u64)
        .flat_map(|p| (0..100u64).map(move |i| (p << 61 | i, i + 1)))
        .collect();
    assert_eq!(store.insert_batch(&items), 800);
    // erase keys 25..50 in every prefix, in one routed batch
    let doomed: Vec<u64> =
        (0..8u64).flat_map(|p| (25..50u64).map(move |i| p << 61 | i)).collect();
    assert_eq!(store.erase_batch(&doomed), 200);
    let rows = store.range(0, u64::MAX - 2);
    assert_eq!(rows.len(), 600);
    assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "sorted after erase");
    assert!(
        rows.iter().all(|&(k, _)| !(25..50).contains(&(k & ((1 << 61) - 1)))),
        "erased window must be gone in every shard"
    );
}
