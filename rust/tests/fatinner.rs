//! Fat-inner routing-block correctness tests (run in CI as the release
//! fat-inner stress step: `CDSKL_SCALE=... cargo test --release -q fatinner_`).
//!
//! Every swept routing-block capacity F must be behaviourally invisible: a
//! `DetSkiplist` at F ∈ {2, 4, 8, 16} on both find modes must track a
//! sequential `BTreeMap` oracle through point churn, fused sorted runs,
//! the interleaved engine and range scans, keep its structural invariants
//! (per-block occupancy/sort/child mirroring at every index level, never
//! stale-LOW separators, 1-2-3-4 arity) through split/merge/borrow
//! boundary hammering with the finger cache both on and off, agree with
//! the oracle through all eight [`StoreKind`] builds, and survive
//! concurrent mixed churn with a quiescent full validation.

use std::collections::BTreeMap;
use std::sync::Arc;

use cdskl::coordinator::ShardedStore;
use cdskl::experiments::hier::T11_KINDS;
use cdskl::mem::ArenaOptions;
use cdskl::numa::Topology;
use cdskl::skiplist::{BatchOp, BatchReply, DetSkiplist, FindMode, DEFAULT_LEAF_CAP};
use cdskl::util::rng::Rng;

/// CDSKL_SCALE divides the op counts, mirroring the experiment harness
/// (CI runs release with CDSKL_SCALE=10 for a deeper soak).
fn scaled(n: u64) -> u64 {
    let scale = std::env::var("CDSKL_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(40u64);
    (n / scale.max(1)).clamp(500, 200_000)
}

/// Narrow leaves keep the tower tall, so the index-level block machinery
/// (split at F full, merge/borrow at F/4) fires constantly.
fn new_sl(mode: FindMode, leaf_cap: usize, inner_cap: usize) -> DetSkiplist {
    DetSkiplist::with_caps_on(mode, 1 << 15, ArenaOptions::default(), leaf_cap, inner_cap)
}

const CAPS: [usize; 4] = [2, 4, 8, 16];

/// Point insert/get/erase churn against the oracle, with periodic and
/// final structural validation (which now checks every routing block), at
/// every swept F on both find modes, fingers on and off.
#[test]
fn fatinner_point_churn_matches_btreemap_oracle() {
    let ops = scaled(40_000);
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        for f in CAPS {
            for fingers in [true, false] {
                let s = new_sl(mode, 4, f);
                s.set_finger_cache(fingers);
                let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
                let mut rng = Rng::new(0x1FA7 + f as u64 + fingers as u64);
                for i in 0..ops {
                    // tight key space: constant re-insert/erase collisions
                    let k = rng.below(ops / 8 + 16) + 1;
                    match rng.below(5) {
                        0 | 1 | 2 => {
                            let fresh = !oracle.contains_key(&k);
                            if fresh {
                                oracle.insert(k, k ^ 7);
                            }
                            assert_eq!(
                                s.insert(k, k ^ 7),
                                fresh,
                                "{mode:?} F={f} fingers={fingers} insert {k}"
                            );
                        }
                        3 => {
                            assert_eq!(
                                s.erase(k),
                                oracle.remove(&k).is_some(),
                                "{mode:?} F={f} fingers={fingers} erase {k}"
                            );
                        }
                        _ => {
                            assert_eq!(
                                s.get(k),
                                oracle.get(&k).copied(),
                                "{mode:?} F={f} fingers={fingers} get {k}"
                            );
                        }
                    }
                    if i % 4096 == 0 {
                        s.check_invariants().unwrap_or_else(|e| {
                            panic!("{mode:?} F={f} fingers={fingers} invariants at op {i}: {e}")
                        });
                    }
                }
                assert_eq!(s.len(), oracle.len() as u64, "{mode:?} F={f}");
                let keys = s.check_invariants().expect("final validation");
                let want: Vec<u64> = oracle.keys().copied().collect();
                assert_eq!(keys, want, "{mode:?} F={f}: terminal walk vs oracle");
            }
        }
    }
}

/// The fused sorted-run path must produce the same replies and end state
/// as the equivalent per-key loop (a twin list), at every F on both modes
/// — runs mix all three op types with duplicate keys.
#[test]
fn fatinner_fused_runs_match_point_twin() {
    let rounds = 6;
    let per_round = scaled(12_000).min(4_000) as usize;
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        for f in CAPS {
            let fused = new_sl(mode, 4, f);
            let twin = new_sl(mode, 4, f);
            let mut rng = Rng::new(0x15ED + f as u64);
            for round in 0..rounds {
                let mut run: Vec<BatchOp> = (0..per_round)
                    .map(|_| {
                        let k = rng.below(per_round as u64 * 2 + 8) + 1;
                        match rng.below(4) {
                            0 | 1 => BatchOp::Insert(k, k ^ 9),
                            2 => BatchOp::Erase(k),
                            _ => BatchOp::Get(k),
                        }
                    })
                    .collect();
                run.sort_by_key(|op| op.key());
                let mut fused_replies = vec![BatchReply::Applied(false); run.len()];
                fused.apply_sorted_run(&run, &mut |i, r| fused_replies[i] = r);
                for (i, op) in run.iter().enumerate() {
                    let want = match *op {
                        BatchOp::Insert(k, v) => BatchReply::Applied(twin.insert(k, v)),
                        BatchOp::Erase(k) => BatchReply::Applied(twin.erase(k)),
                        BatchOp::Get(k) => BatchReply::Value(twin.get(k)),
                    };
                    assert_eq!(
                        fused_replies[i], want,
                        "{mode:?} F={f} round {round} op {i} ({op:?})"
                    );
                }
                let fk = fused.check_invariants().expect("fused invariants");
                let tk = twin.check_invariants().expect("twin invariants");
                assert_eq!(fk, tk, "{mode:?} F={f} round {round}: end states diverged");
            }
        }
    }
}

/// The interleaved engine (scattered-batch MLP path, now block-routing at
/// the index levels) must agree with the oracle for lookups (`get_many`)
/// and with the fused path for mixed runs (`apply_interleaved`), at every
/// F with fingers on and off.
#[test]
fn fatinner_interleaved_matches_oracle() {
    let n = scaled(20_000);
    for f in CAPS {
        for fingers in [true, false] {
            let s = new_sl(FindMode::LockFree, 4, f);
            s.set_finger_cache(fingers);
            let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
            // scattered resident set (odd stride keeps neighbours far apart)
            for i in 0..n {
                let k = i * 173 + 5;
                assert!(s.insert(k, i));
                oracle.insert(k, i);
            }
            // unsorted scattered probes, half misses, through every width
            let mut rng = Rng::new(0x211 + f as u64);
            let probes: Vec<u64> = (0..scaled(8_000)).map(|_| rng.below(n * 173 + 10)).collect();
            for width in [1usize, 4, 8] {
                let got = s.get_many(&probes, width);
                for (i, &k) in probes.iter().enumerate() {
                    assert_eq!(
                        got[i],
                        oracle.get(&k).copied(),
                        "F={f} fingers={fingers} width {width} get {k}"
                    );
                }
            }
            // mixed interleaved run vs its oracle effect
            let mut run: Vec<BatchOp> = (0..scaled(4_000))
                .map(|_| {
                    let k = rng.below(n * 173 + 10);
                    match rng.below(3) {
                        0 => BatchOp::Insert(k, k ^ 1),
                        1 => BatchOp::Erase(k),
                        _ => BatchOp::Get(k),
                    }
                })
                .collect();
            run.sort_by_key(|op| op.key());
            s.apply_interleaved(&run, 8, &mut |i, r| {
                let want = match run[i] {
                    BatchOp::Insert(k, v) => {
                        let fresh = !oracle.contains_key(&k);
                        if fresh {
                            oracle.insert(k, v);
                        }
                        BatchReply::Applied(fresh)
                    }
                    BatchOp::Erase(k) => BatchReply::Applied(oracle.remove(&k).is_some()),
                    BatchOp::Get(k) => BatchReply::Value(oracle.get(&k).copied()),
                };
                assert_eq!(r, want, "F={f} interleaved op {i} ({:?})", run[i]);
            });
            assert_eq!(s.len(), oracle.len() as u64, "F={f}");
            s.check_invariants().expect("post-interleave validation");
        }
    }
}

/// Boundary hammer: ascending fill (every block split fires at exactly F
/// full, and every new max retracts/republishes the rightmost spine
/// blocks) then descending erase (merge/borrow fires at exactly F/4),
/// validating the per-level block invariants at tight intervals. Narrow
/// leaves (K = 2) force the tallest towers the capacity allows.
#[test]
fn fatinner_split_merge_boundary_hammer() {
    let n = scaled(6_000);
    for f in CAPS {
        let s = new_sl(FindMode::LockFree, 2, f);
        for i in 0..n {
            assert!(s.insert(i + 1, i));
            if i % (f as u64) == f as u64 - 1 {
                s.check_invariants().unwrap_or_else(|e| panic!("F={f} fill at {i}: {e}"));
            }
        }
        // descending erase drains the rightmost blocks first: constant
        // underflow (and depth decreases) at the moving boundary
        for i in (0..n).rev() {
            assert!(s.erase(i + 1), "F={f} erase {}", i + 1);
            if i % (f as u64) == 0 {
                s.check_invariants().unwrap_or_else(|e| panic!("F={f} drain at {i}: {e}"));
            }
        }
        assert_eq!(s.len(), 0);
        // striped erase from a fresh fill: merges and borrows between
        // interior blocks at every level
        for i in 0..n {
            s.insert(i + 1, i);
        }
        let mut left = n;
        for i in 0..n {
            if i % 4 != 3 {
                assert!(s.erase(i + 1));
                left -= 1;
            }
            if i % 512 == 0 {
                s.check_invariants().unwrap_or_else(|e| panic!("F={f} stripe at {i}: {e}"));
            }
        }
        assert_eq!(s.len(), left);
        s.check_invariants().expect("post-stripe validation");
    }
}

/// All eight [`StoreKind`] builds at every swept F (including F = 1, the
/// block-disabled baseline) must track a `BTreeMap` oracle through point
/// churn and range sweeps, with the finger cache on and off — the block
/// capacity must never leak into answers, whatever the store around it.
#[test]
fn fatinner_all_kinds_oracle_with_fingers_toggled() {
    let ops = scaled(12_000);
    for f in [1usize, 2, 4, 8, 16] {
        for fingers in [true, false] {
            for kind in T11_KINDS {
                let s = ShardedStore::with_caps(
                    kind,
                    2,
                    1 << 14,
                    Topology::virtual_grid(2, 2),
                    2,
                    Some(DEFAULT_LEAF_CAP),
                    Some(f),
                );
                s.set_finger_cache(fingers);
                let mut oracle: BTreeMap<u64, u64> = BTreeMap::new();
                let mut rng = Rng::new(kind as u64 ^ 0x5EED ^ (f as u64) << 8 ^ fingers as u64);
                for i in 0..ops {
                    let k = rng.below(ops / 4 + 8) + 1;
                    match rng.below(5) {
                        0 | 1 | 2 => {
                            let fresh = !oracle.contains_key(&k);
                            if fresh {
                                oracle.insert(k, k + 3);
                            }
                            assert_eq!(
                                s.insert(k, k + 3),
                                fresh,
                                "{kind:?} F={f} fingers={fingers} insert {k} at op {i}"
                            );
                        }
                        3 => {
                            assert_eq!(
                                s.erase(k),
                                oracle.remove(&k).is_some(),
                                "{kind:?} F={f} fingers={fingers} erase {k} at op {i}"
                            );
                        }
                        _ => {
                            assert_eq!(
                                s.get(k),
                                oracle.get(&k).copied(),
                                "{kind:?} F={f} fingers={fingers} get {k} at op {i}"
                            );
                        }
                    }
                }
                let want: Vec<(u64, u64)> = oracle.iter().map(|(&k, &v)| (k, v)).collect();
                assert_eq!(s.range(0, u64::MAX - 2), want, "{kind:?} F={f} final sweep");
                assert_eq!(s.len(), want.len() as u64, "{kind:?} F={f} len");
            }
        }
    }
}

/// Concurrent mixed churn at fat-inner capacities: disjoint per-thread key
/// ranges (every reply assertable) plus a shared contended stripe, on both
/// find modes, with a quiescent full validation (including every routing
/// block) at the end.
#[test]
fn fatinner_concurrent_churn_validates_quiescently() {
    let per_thread = scaled(8_000).min(6_000);
    for mode in [FindMode::LockFree, FindMode::ReadLocked] {
        for f in [4usize, 8] {
            let s = Arc::new(DetSkiplist::with_caps_on(
                mode,
                1 << 16,
                ArenaOptions::default(),
                4,
                f,
            ));
            let threads = 6u64;
            std::thread::scope(|scope| {
                for t in 0..threads {
                    let s = s.clone();
                    scope.spawn(move || {
                        let mut rng = Rng::new(0xD0D0 + t);
                        let base = (t + 1) << 40; // disjoint range per thread
                        let mut mine: BTreeMap<u64, u64> = BTreeMap::new();
                        for i in 0..per_thread {
                            let k = base + rng.below(per_thread / 2 + 8);
                            match rng.below(4) {
                                0 | 1 => {
                                    let fresh = !mine.contains_key(&k);
                                    if fresh {
                                        mine.insert(k, t);
                                    }
                                    assert_eq!(s.insert(k, t), fresh, "t{t} insert {k}");
                                }
                                2 => {
                                    assert_eq!(
                                        s.erase(k),
                                        mine.remove(&k).is_some(),
                                        "t{t} erase {k}"
                                    );
                                }
                                _ => {
                                    assert_eq!(s.get(k), mine.get(&k).copied(), "t{t} get {k}");
                                }
                            }
                            // shared stripe: pure contention, no asserts on
                            // outcome, but values must carry the key
                            let sk = rng.below(64);
                            if i % 3 == 0 {
                                s.insert(sk, sk);
                            } else if let Some(v) = s.get(sk) {
                                assert_eq!(v, sk, "shared key {sk} tore");
                            }
                        }
                        mine.len() as u64
                    });
                }
            });
            s.check_invariants()
                .unwrap_or_else(|e| panic!("{mode:?} F={f} quiescent validation: {e}"));
        }
    }
}

/// Concurrent fused runs from several threads over disjoint key stripes
/// (the owner-side combining shape), then full validation — exercises
/// block split/merge under the run path's window gating concurrently.
#[test]
fn fatinner_concurrent_fused_runs() {
    let per_run = scaled(4_000).min(2_000) as usize;
    for f in [4usize, 8, 16] {
        let s = Arc::new(DetSkiplist::with_caps_on(
            FindMode::LockFree,
            1 << 16,
            ArenaOptions::default(),
            4,
            f,
        ));
        let threads = 4u64;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let s = s.clone();
                scope.spawn(move || {
                    let base = (t + 1) << 40;
                    let mut rng = Rng::new(0x100D + t);
                    for round in 0..6u64 {
                        let mut run: Vec<BatchOp> = (0..per_run)
                            .map(|_| {
                                let k = base + rng.below(per_run as u64 * 2);
                                if round % 2 == 0 || rng.below(3) > 0 {
                                    BatchOp::Insert(k, t)
                                } else {
                                    BatchOp::Erase(k)
                                }
                            })
                            .collect();
                        run.sort_by_key(|op| op.key());
                        s.apply_sorted_run(&run, &mut |_, _| {});
                    }
                });
            }
        });
        let keys = s.check_invariants().expect("post-run validation");
        assert_eq!(keys.len() as u64, s.len(), "walk vs len");
        // every surviving key must carry its stripe owner's id
        for &k in keys.iter() {
            let owner = (k >> 40) - 1;
            assert_eq!(s.get(k), Some(owner), "key {k} crossed stripes");
        }
    }
}
