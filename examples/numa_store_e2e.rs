//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! Layer 1/2 (Pallas/JAX, AOT): `make artifacts` compiles the fused
//! keygen→hash→shard→slot pipeline to HLO text. This binary loads it via
//! PJRT (layer 3), self-checks it bit-exactly against the native mixer,
//! generates the paper's workload-1 and workload-2 streams with it, routes
//! keys through per-thread lock-free queues to NUMA-local workers, and runs
//! them against the hierarchical deterministic-skiplist store — reporting
//! the paper's headline metrics (whole-workload seconds vs threads,
//! throughput, NUMA locality). Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example numa_store_e2e [OPS]
//! ```

use std::sync::Arc;

use cdskl::coordinator::{run_workload, ShardedStore, StoreKind};
use cdskl::numa::Topology;
use cdskl::runtime::KeyRouter;
use cdskl::workload::{OpMix, WorkloadSpec};

fn main() {
    let ops: u64 = std::env::args()
        .nth(1)
        .and_then(|s| cdskl::util::cli::parse_u64_with_suffix(&s))
        .unwrap_or(1_000_000);
    let topo = Topology::milan_virtual();
    let router = KeyRouter::auto("artifacts");
    println!(
        "e2e: {} ops | virtual topology {}x{} | key router: {}",
        ops,
        topo.numa_nodes,
        topo.cpus_per_node,
        if router.is_aot() { "AOT (PJRT, self-checked)" } else { "native fallback" }
    );

    println!("\n| workload | store | threads | fill(s) | drain(s) | Mops/s | find-hit% | remote% |");
    println!("|---|---|---|---|---|---|---|---|");
    for (wname, mix) in [("w1 (10%I/90%F)", OpMix::W1), ("w2 (+0.2%E)", OpMix::W2)] {
        for threads in [4usize, 16, 64] {
            for kind in [StoreKind::DetSkiplistLf, StoreKind::RandomSkiplist] {
                let store = Arc::new(ShardedStore::new(
                    kind,
                    8,
                    (ops as usize / 4).max(1 << 16),
                    topo.clone(),
                    threads,
                ));
                let spec = WorkloadSpec::new("e2e", ops, mix, (ops / 2).max(1 << 16));
                let m = run_workload(&store, &spec, threads, &router, 0xE2E);
                println!(
                    "| {} | {} | {} | {:.3} | {:.3} | {:.3} | {:.1} | {:.2} |",
                    wname,
                    store.kind_name(),
                    threads,
                    m.fill_seconds,
                    m.drain_seconds,
                    m.throughput_mops(),
                    m.found as f64 / m.finds.max(1) as f64 * 100.0,
                    m.remote_accesses as f64 / (m.local_accesses + m.remote_accesses).max(1) as f64
                        * 100.0,
                );
                assert_eq!(m.ops(), ops, "every routed op must execute exactly once");
            }
        }
    }
    println!("\ne2e OK: all layers composed (AOT artifacts -> PJRT -> router -> shards)");
}
