//! Quickstart: the three data structures in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cdskl::hashtable::{ConcurrentMap, TwoLevelSpoHashMap};
use cdskl::queue::{ConcurrentQueue, LfQueue};
use cdskl::skiplist::{DetSkiplist, FindMode};
use std::sync::Arc;

fn main() {
    // --- concurrent deterministic 1-2-3-4 skiplist (the paper's headline) ---
    let skiplist = Arc::new(DetSkiplist::new(FindMode::LockFree));
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let sl = skiplist.clone();
            s.spawn(move || {
                for i in 0..10_000u64 {
                    sl.insert(t * 100_000 + i, i);
                }
            });
        }
    });
    println!("skiplist: {} keys, sorted & balanced", skiplist.len());
    println!("skiplist: get(100007) = {:?}", skiplist.get(100_007));
    println!("skiplist: range(5..12) = {:?}", skiplist.range(5, 12));
    skiplist.check_invariants().expect("1-2-3-4 invariants hold");

    // --- unbounded lock-free queue with block recycling ---
    let queue = Arc::new(LfQueue::new());
    std::thread::scope(|s| {
        let q = queue.clone();
        s.spawn(move || {
            for i in 0..100_000u64 {
                q.push(i);
            }
        });
        let q = queue.clone();
        s.spawn(move || {
            let mut got = 0u64;
            while got < 100_000 {
                if q.pop().is_some() {
                    got += 1;
                }
            }
        });
    });
    let st = queue.stats();
    println!(
        "queue: {} pushes / {} pops, {} blocks allocated, {} recycled",
        st.pushes, st.pops, st.blocks_allocated, st.blocks_recycled
    );

    // --- hierarchical split-order hash table (the paper's best) ---
    let map = Arc::new(TwoLevelSpoHashMap::new());
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let m = map.clone();
            s.spawn(move || {
                for i in 0..10_000u64 {
                    m.insert(t << 32 | i, i * 2);
                }
            });
        }
    });
    println!("hash table: {} entries, get(7) = {:?}", map.len(), map.get(7));
    println!("quickstart OK");
}
