//! Producer/consumer load-balancing pipeline over the paper's lock-free
//! queue (§III motivation: "load balancing workloads within and across
//! nodes in many-core processors").
//!
//! A stage-1 pool parses "requests" (scrambles keys), pushes to per-worker
//! queues chosen by NUMA region; a stage-2 pool pops NUMA-locally and
//! aggregates. Demonstrates block recycling keeping the memory footprint
//! flat across a long stream.
//!
//! ```bash
//! cargo run --release --example queue_pipeline
//! ```

use cdskl::numa::Topology;
use cdskl::queue::{ConcurrentQueue, LfQueue};
use cdskl::util::rng::{mix64, Rng};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn main() {
    let topo = Topology::virtual_grid(2, 2);
    let producers = 2usize;
    let consumers = 4usize; // one queue per consumer
    let per_producer = 200_000u64;

    let queues: Arc<Vec<LfQueue>> =
        Arc::new((0..consumers).map(|_| LfQueue::with_config(1024, 64, true)).collect());
    let consumed = Arc::new(AtomicU64::new(0));
    let checksum = Arc::new(AtomicU64::new(0));

    std::thread::scope(|s| {
        for p in 0..producers {
            let queues = queues.clone();
            let topo = topo.clone();
            s.spawn(move || {
                let mut rng = Rng::new(p as u64);
                let node = topo.node_of_cpu(p);
                let region: Vec<usize> =
                    (0..queues.len()).filter(|&c| topo.node_of_cpu(c) == node).collect();
                for i in 0..per_producer {
                    let work = mix64(p as u64 * per_producer + i);
                    let target = region[rng.below(region.len() as u64) as usize];
                    queues[target].push(work);
                }
            });
        }
        for c in 0..consumers {
            let queues = queues.clone();
            let consumed = consumed.clone();
            let checksum = checksum.clone();
            s.spawn(move || {
                let total = (producers as u64) * per_producer;
                let mut empties = 0;
                loop {
                    match queues[c].pop() {
                        Some(v) => {
                            empties = 0;
                            checksum.fetch_xor(v, Ordering::Relaxed);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        None => {
                            if consumed.load(Ordering::Relaxed) >= total {
                                break;
                            }
                            empties += 1;
                            if empties > 1_000_000 {
                                break; // producers stalled? bail out
                            }
                            std::thread::yield_now();
                        }
                    }
                }
            });
        }
    });

    let total = (producers as u64) * per_producer;
    assert_eq!(consumed.load(Ordering::Relaxed), total, "no element lost");
    // reference checksum: xor of everything produced
    let mut want = 0u64;
    for p in 0..producers as u64 {
        for i in 0..per_producer {
            want ^= mix64(p * per_producer + i);
        }
    }
    assert_eq!(checksum.load(Ordering::Relaxed), want, "payload integrity");
    let blocks: u64 = queues.iter().map(|q| q.stats().blocks_allocated).sum();
    let recycled: u64 = queues.iter().map(|q| q.stats().blocks_recycled).sum();
    println!(
        "queue_pipeline OK: {total} items, {blocks} blocks allocated, {recycled} recycled \
         (footprint stays flat: {:.1} items/block-alloc)",
        total as f64 / blocks as f64
    );
    assert!(recycled > 0, "long stream must recycle blocks");
}
