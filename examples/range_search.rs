//! Range-search scenario (paper §IX: "Skiplists are more convenient than
//! binary search trees for range searches because of the terminal
//! linked-list").
//!
//! Models a time-series store: concurrent writers append timestamped
//! samples while readers run sliding-window range queries against the
//! deterministic skiplist — lock-free reads, no global locks.
//!
//! ```bash
//! cargo run --release --example range_search
//! ```

use cdskl::skiplist::{DetSkiplist, FindMode};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

fn main() {
    let store = Arc::new(DetSkiplist::with_capacity(FindMode::LockFree, 1 << 20));
    let stop = Arc::new(AtomicBool::new(false));
    let max_ts = Arc::new(AtomicU64::new(0));
    let writers = 3usize;
    let per_writer = 50_000u64;

    std::thread::scope(|s| {
        // writers: interleaved "timestamps" (writer w owns ts ≡ w mod 3)
        for w in 0..writers as u64 {
            let store = store.clone();
            let max_ts = max_ts.clone();
            s.spawn(move || {
                for i in 0..per_writer {
                    let ts = i * writers as u64 + w;
                    store.insert(ts, w << 32 | i);
                    max_ts.fetch_max(ts, Ordering::Relaxed);
                }
            });
        }
        // readers: sliding windows over whatever is present
        for _ in 0..2 {
            let store = store.clone();
            let stop = stop.clone();
            let max_ts = max_ts.clone();
            s.spawn(move || {
                let mut windows = 0u64;
                let mut total = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let hi = max_ts.load(Ordering::Relaxed);
                    let lo = hi.saturating_sub(1_000);
                    let rows = store.range(lo, hi);
                    // results must be sorted and within bounds
                    assert!(rows.windows(2).all(|w| w[0].0 < w[1].0));
                    assert!(rows.iter().all(|&(k, _)| k >= lo && k <= hi));
                    windows += 1;
                    total += rows.len() as u64;
                }
                println!("reader: {windows} windows, {total} rows scanned");
            });
        }
        // let writers finish, then stop readers
        s.spawn({
            let stop = stop.clone();
            move || {
                std::thread::sleep(std::time::Duration::from_millis(1500));
                stop.store(true, Ordering::Relaxed);
            }
        });
    });

    let n = writers as u64 * per_writer;
    assert_eq!(store.len(), n);
    // final full-range scan: exactly every timestamp
    let all = store.range(0, u64::MAX - 2);
    assert_eq!(all.len() as u64, n);
    println!("range_search OK: {} samples, windows consistent under concurrency", n);
}
