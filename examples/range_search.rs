//! Range-search scenario across the NUMA-sharded store (paper §IX:
//! "Skiplists are more convenient than binary search trees for range
//! searches because of the terminal linked-list", plus the §VI 3-MSB key
//! partition).
//!
//! Models a time-series store sharded by source (the 3 key MSBs pick the
//! shard, i.e. the NUMA node owning that source group): history is
//! bulk-loaded through the per-shard batch path, then concurrent writers
//! append timestamped samples to every shard while readers run per-source
//! sliding windows and full cross-shard scans — per-shard results
//! concatenate in prefix order, so scans are globally sorted with no merge
//! step.
//!
//! ```bash
//! cargo run --release --example range_search
//! ```

use cdskl::coordinator::{ShardedStore, StoreKind};
use cdskl::numa::Topology;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

const SOURCES: u64 = 8; // one per shard / NUMA node
const HISTORY_PER_SOURCE: u64 = 20_000;
const LIVE_PER_WRITER: u64 = 30_000;

fn key(source: u64, ts: u64) -> u64 {
    source << 61 | ts
}

fn main() {
    let store = Arc::new(ShardedStore::new(
        StoreKind::DetSkiplistLf,
        SOURCES as usize,
        1 << 20,
        Topology::milan_virtual(),
        8,
    ));

    // ---- bulk load the history through the routed batch path ----
    let history: Vec<(u64, u64)> = (0..SOURCES)
        .flat_map(|s| (0..HISTORY_PER_SOURCE).map(move |ts| (key(s, ts * 2), s)))
        .collect();
    let loaded = store.insert_batch(&history);
    assert_eq!(loaded, SOURCES * HISTORY_PER_SOURCE);
    println!("bulk-loaded {} history samples across {} shards", loaded, store.num_shards());

    let stop = Arc::new(AtomicBool::new(false));
    let max_ts = Arc::new(AtomicU64::new(HISTORY_PER_SOURCE * 2));
    std::thread::scope(|scope| {
        // writers: one per source, appending odd "live" timestamps
        let mut writers = Vec::new();
        for s in 0..SOURCES {
            let store = store.clone();
            let max_ts = max_ts.clone();
            writers.push(scope.spawn(move || {
                for i in 0..LIVE_PER_WRITER {
                    let ts = HISTORY_PER_SOURCE * 2 + i * 2 + 1;
                    store.insert(key(s, ts), s);
                    max_ts.fetch_max(ts, Ordering::Relaxed);
                }
            }));
        }
        // readers: per-source sliding windows + full cross-shard scans
        for r in 0..2u64 {
            let store = store.clone();
            let stop = stop.clone();
            let max_ts = max_ts.clone();
            scope.spawn(move || {
                let mut windows = 0u64;
                let mut rows_total = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // sliding window on one source (single-shard fast path)
                    let s = (windows + r) % SOURCES;
                    let hi = max_ts.load(Ordering::Relaxed);
                    let lo = hi.saturating_sub(2_000);
                    let rows = store.range(key(s, lo), key(s, hi));
                    assert!(rows.windows(2).all(|w| w[0].0 < w[1].0), "window sorted");
                    assert!(rows.iter().all(|&(k, v)| k >> 61 == s && v == s));
                    rows_total += rows.len() as u64;
                    // cross-shard scan over the same time slice of EVERY
                    // source: per-prefix results concatenate already sorted
                    let recent: Vec<(u64, u64)> = (0..SOURCES)
                        .flat_map(|src| store.range(key(src, lo), key(src, hi)))
                        .collect();
                    assert!(recent.windows(2).all(|w| w[0].0 < w[1].0), "global order");
                    windows += 1;
                }
                println!("reader {r}: {windows} windows, {rows_total} rows scanned");
            });
        }
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
    });

    // ---- quiescent validation ----
    let expect = SOURCES * (HISTORY_PER_SOURCE + LIVE_PER_WRITER);
    assert_eq!(store.len(), expect);
    let all = store.range(0, u64::MAX - 2);
    assert_eq!(all.len() as u64, expect, "full cross-shard scan sees every sample");
    assert!(all.windows(2).all(|w| w[0].0 < w[1].0), "globally sorted, no merge step");
    let st = store.stats();
    println!(
        "range_search OK: {} samples, {} splits / {} find-retries across shards",
        expect,
        st.splits,
        st.find_retries
    );
}
