"""L2: the jitted compute graph the rust coordinator loads via PJRT.

Two exported entry points, both calling the L1 Pallas kernels:

- ``route_batch(base, m)``   -> (key, hash, shard, slot) u64[N] each.
  The full per-batch data path of the paper's hierarchical design
  (workload key stream -> boost-style H(k) -> NUMA shard -> table slot).
- ``route_stats(base, m)``   -> same plus the per-shard load histogram used
  for router accounting.

Shapes are static per artifact; ``aot.py`` lowers one artifact per batch
size in ``BATCH_SIZES``.  The rust runtime picks the artifact matching its
configured batch and pads the tail batch.
"""

import jax
import jax.numpy as jnp

from .kernels import route, shard_histogram

# Per-artifact static batch sizes. 4096 covers latency-sensitive small
# batches; 65536 amortizes PJRT dispatch on the bulk path (one BLOCK).
BATCH_SIZES = (4096, 65536)


def make_route_batch(n: int):
    def route_batch(base: jnp.ndarray, m: jnp.ndarray):
        key, h, shard, slot = route(base, m, n)
        return key, h, shard, slot

    return route_batch


def make_route_stats(n: int):
    def route_stats(base: jnp.ndarray, m: jnp.ndarray):
        key, h, shard, slot = route(base, m, n)
        hist = shard_histogram(shard)
        return key, h, shard, slot, hist

    return route_stats


def scalar_spec():
    return jax.ShapeDtypeStruct((1,), jnp.uint64)
