"""AOT lowering: jit -> stablehlo -> XlaComputation -> HLO *text*.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits:  route_batch_<N>.hlo.txt, route_stats_<N>.hlo.txt for N in BATCH_SIZES,
        plus manifest.txt recording shapes for the rust loader.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import BATCH_SIZES, make_route_batch, make_route_stats, scalar_spec


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn) -> str:
    return to_hlo_text(jax.jit(fn).lower(scalar_spec(), scalar_spec()))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for n in BATCH_SIZES:
        for name, fn in (
            (f"route_batch_{n}", make_route_batch(n)),
            (f"route_stats_{n}", make_route_stats(n)),
        ):
            text = lower_fn(fn)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest.append(f"{name} batch={n}")
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
