"""L1 Pallas kernel: per-shard load histogram.

Counts how many keys of a batch land on each of the ``2**SHARD_BITS`` NUMA
shards.  The coordinator uses this for the load-balance analytics behind the
paper's "all slots were load balanced with approximately N/M entries" claim
(§VIII) and for the router's queue-depth accounting (§VI).

Implementation: one-hot compare + reduce per grid step, accumulated across
grid steps in the output ref (grid iterations run sequentially on a core, so
the read-modify-write accumulation is race-free).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hash_mix import BLOCK
from .route import SHARD_BITS

NSHARDS = 1 << SHARD_BITS


def _hist_kernel(shard_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = shard_ref[...]
    ids = jnp.arange(NSHARDS, dtype=jnp.uint64)
    onehot = (s[None, :] == ids[:, None]).astype(jnp.uint64)
    o_ref[...] += onehot.sum(axis=1)


def shard_histogram(shard: jnp.ndarray) -> jnp.ndarray:
    """u64[NSHARDS] counts for a u64[n] shard-id vector."""
    n = shard.shape[0]
    bs = BLOCK if (n % BLOCK == 0 and n >= BLOCK) else n
    grid = n // bs
    return pl.pallas_call(
        _hist_kernel,
        out_shape=jax.ShapeDtypeStruct((NSHARDS,), jnp.uint64),
        grid=(grid,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=pl.BlockSpec((NSHARDS,), lambda i: (0,)),
        interpret=True,
    )(shard)
