"""L1: Pallas kernels for the workload/routing data path (build-time only).

x64 must be enabled before any jax array work in this package: keys, hashes
and slots are genuine u64 quantities (the paper packs 64-bit keys next to
64-bit pointers), and the rust side consumes u64 buffers.
"""

import jax

jax.config.update("jax_enable_x64", True)

from .hash_mix import BLOCK, hash_mix, splitmix64_mix  # noqa: E402,F401
from .keygen import keygen  # noqa: E402,F401
from .route import SHARD_BITS, route  # noqa: E402,F401
from .histogram import NSHARDS, shard_histogram  # noqa: E402,F401
