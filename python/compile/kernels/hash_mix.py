"""L1 Pallas kernel: 64-bit key scrambler (splitmix64 finalizer).

Stands in for ``boost::hash<uint64_t>`` in the paper: its only job is to
decorrelate key bits so that ``slot = H(k) mod M`` (power-of-two M) and the
NUMA shard id (top 3 bits) are uniformly distributed.  The exact mixer is
splitmix64's finalizer (Steele et al., "Fast splittable pseudorandom number
generators"), chosen because it is a bijection on u64 (no collisions are
introduced) and has a well-known test vector (splitmix64(0) =
0xe220a8397b1dcdaf) that the rust side asserts against at artifact load.

Pallas notes: the kernel is element-wise over a 1-D block of u64 lanes.  On a
real TPU this is VPU work (no MXU); the BlockSpec tiles the stream in
``BLOCK``-sized chunks so the HBM->VMEM schedule double-buffers cleanly.  The
CPU artifact is lowered with ``interpret=True`` (Mosaic custom-calls cannot run
on the CPU PJRT plugin).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size for the Pallas grid. 64Ki u64 lanes = 512 KiB per operand block,
# comfortably inside a TPU core's ~16 MiB VMEM with double buffering.
BLOCK = 65536

_C1 = 0x9E3779B97F4A7C15
_C2 = 0xBF58476D1CE4E5B9
_C3 = 0x94D049BB133111EB


def splitmix64_mix(x: jnp.ndarray) -> jnp.ndarray:
    """The splitmix64 finalizer as traceable u64 ops (used inside kernels)."""
    x = x + jnp.uint64(_C1)
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(_C2)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(_C3)
    return x ^ (x >> jnp.uint64(31))


def _hash_mix_kernel(x_ref, o_ref):
    o_ref[...] = splitmix64_mix(x_ref[...])


def hash_mix(x: jnp.ndarray) -> jnp.ndarray:
    """H(k) for a batch of u64 keys via a Pallas kernel.

    ``x`` must be 1-D u64. Sizes that are not a multiple of BLOCK use a single
    whole-array block (small-batch path); multiples use the tiled grid.
    """
    n = x.shape[0]
    if n % BLOCK == 0 and n > BLOCK:
        grid = n // BLOCK
        return pl.pallas_call(
            _hash_mix_kernel,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.uint64),
            grid=(grid,),
            in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
            out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
            interpret=True,
        )(x)
    return pl.pallas_call(
        _hash_mix_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint64),
        interpret=True,
    )(x)
