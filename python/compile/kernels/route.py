"""L1 Pallas kernel: fused keygen -> hash -> shard -> slot routing.

This is the arithmetic the paper's hierarchical design runs in front of every
data-structure operation (sections VI-VIII):

  key   = splitmix64(base + i)          (workload key stream)
  H(k)  = splitmix64(key)               (boost-hash stand-in, §VIII eq. 8)
  shard = key >> 61                     (top ``SHARD_BITS``=3 MSBs -> 8 NUMA shards, §VI)
  slot  = H(k) & (M - 1)                (power-of-two table of M slots, §VIII)

Fusing the four stages into one kernel keeps the stream in VMEM for a single
HBM round-trip on a real TPU; under ``interpret=True`` on CPU it lowers to a
single fused elementwise HLO loop.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hash_mix import BLOCK, splitmix64_mix

SHARD_BITS = 3  # 8 NUMA shards, matching the paper's 8-NUMA-node Milan.


def _route_kernel(base_ref, m_ref, key_ref, hash_ref, shard_ref, slot_ref):
    i = pl.program_id(0)
    n = key_ref.shape[0]
    start = base_ref[0] + jnp.uint64(i) * jnp.uint64(n)
    ctr = start + jnp.arange(n, dtype=jnp.uint64)
    key = splitmix64_mix(ctr)
    h = splitmix64_mix(key)
    key_ref[...] = key
    hash_ref[...] = h
    shard_ref[...] = key >> jnp.uint64(64 - SHARD_BITS)
    slot_ref[...] = h & (m_ref[0] - jnp.uint64(1))


def route(base: jnp.ndarray, m: jnp.ndarray, n: int):
    """Route ``n`` generated keys. ``base``/``m`` are shape-(1,) u64 scalars.

    Returns (key, hash, shard, slot), each u64[n].
    """
    bs = BLOCK if (n % BLOCK == 0 and n >= BLOCK) else n
    grid = n // bs
    out = jax.ShapeDtypeStruct((n,), jnp.uint64)
    return pl.pallas_call(
        _route_kernel,
        out_shape=(out, out, out, out),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=tuple(pl.BlockSpec((bs,), lambda i: (i,)) for _ in range(4)),
        interpret=True,
    )(base, m)
