"""Pure-jnp oracles for every Pallas kernel (the correctness baseline).

These never use pallas; pytest asserts kernel == ref bit-exactly.  Golden
test vectors for splitmix64 are pinned here too so drift in either layer is
caught (the rust side pins the same vectors in ``hashtable/hash.rs``).
"""

import jax.numpy as jnp

from .route import SHARD_BITS

# mix(i) = splitmix64-finalize(i + GAMMA) for i = 0..4.  mix(0) is the first
# output of the canonical splitmix64 stream seeded with 0 (0xE220A8397B1DCDAF);
# the rest follow from applying the finalizer to i+GAMMA directly (we hash
# counters, we do not iterate stream state).
GOLDEN = [
    0xE220A8397B1DCDAF,
    0x910A2DEC89025CC1,
    0x975835DE1C9756CE,
    0x1D0B14E4DB018FED,
    0x6E73E372E2338ACA,
]


def splitmix64_ref(x: jnp.ndarray) -> jnp.ndarray:
    x = x + jnp.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    return x ^ (x >> jnp.uint64(31))


def keygen_ref(base: int, n: int) -> jnp.ndarray:
    ctr = jnp.uint64(base) + jnp.arange(n, dtype=jnp.uint64)
    return splitmix64_ref(ctr)


def route_ref(base: int, m: int, n: int):
    key = keygen_ref(base, n)
    h = splitmix64_ref(key)
    shard = key >> jnp.uint64(64 - SHARD_BITS)
    slot = h & jnp.uint64(m - 1)
    return key, h, shard, slot


def shard_histogram_ref(shard: jnp.ndarray) -> jnp.ndarray:
    nshards = 1 << SHARD_BITS
    return jnp.bincount(shard.astype(jnp.int64), length=nshards).astype(jnp.uint64)
