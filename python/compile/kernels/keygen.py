"""L1 Pallas kernel: counter-based 64-bit key stream.

The paper generates workload keys "using hash functions from boost" — i.e. a
scrambled counter.  We reproduce that as a stateless splitmix64 stream:
``key[i] = splitmix64(base + i)``.  Stateless-ness matters for the rust
coordinator: any worker can regenerate any slice of the workload from
``(seed, base)`` without coordination, and the rust fallback
(``workload::gen``) is bit-identical.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hash_mix import BLOCK, splitmix64_mix


def _keygen_kernel(base_ref, o_ref):
    i = pl.program_id(0)
    n = o_ref.shape[0]
    start = base_ref[0] + jnp.uint64(i) * jnp.uint64(n)
    ctr = start + jnp.arange(n, dtype=jnp.uint64)
    o_ref[...] = splitmix64_mix(ctr)


def keygen(base: jnp.ndarray, n: int) -> jnp.ndarray:
    """Generate ``n`` keys for counter base ``base`` (shape (1,) u64)."""
    bs = BLOCK if (n % BLOCK == 0 and n >= BLOCK) else n
    grid = n // bs
    return pl.pallas_call(
        _keygen_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint64),
        grid=(grid,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        interpret=True,
    )(base)
