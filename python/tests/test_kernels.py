"""Kernel-vs-ref bit-exactness: the core L1 correctness signal.

hypothesis sweeps batch shapes and input values; everything is integer math,
so comparisons are exact equality (assert_array_equal), not allclose.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_array_equal

from compile.kernels import BLOCK, NSHARDS, hash_mix, keygen, route, shard_histogram
from compile.kernels.ref import (
    GOLDEN,
    keygen_ref,
    route_ref,
    shard_histogram_ref,
    splitmix64_ref,
)

U64 = st.integers(min_value=0, max_value=2**64 - 1)
SIZES = st.sampled_from([1, 2, 7, 64, 1000, 4096, 8192])


def test_golden_vectors():
    x = jnp.arange(len(GOLDEN), dtype=jnp.uint64)
    got = [int(v) for v in hash_mix(x)]
    assert got == GOLDEN


def test_golden_vectors_ref():
    x = jnp.arange(len(GOLDEN), dtype=jnp.uint64)
    got = [int(v) for v in splitmix64_ref(x)]
    assert got == GOLDEN


@settings(max_examples=25, deadline=None)
@given(vals=st.lists(U64, min_size=1, max_size=512))
def test_hash_mix_matches_ref(vals):
    x = jnp.array(vals, dtype=jnp.uint64)
    assert_array_equal(np.asarray(hash_mix(x)), np.asarray(splitmix64_ref(x)))


@settings(max_examples=15, deadline=None)
@given(base=U64, n=SIZES)
def test_keygen_matches_ref(base, n):
    got = keygen(jnp.array([base], dtype=jnp.uint64), n)
    assert_array_equal(np.asarray(got), np.asarray(keygen_ref(base, n)))


@settings(max_examples=15, deadline=None)
@given(base=U64, logm=st.integers(min_value=0, max_value=20), n=SIZES)
def test_route_matches_ref(base, logm, n):
    m = 1 << logm
    got = route(
        jnp.array([base], dtype=jnp.uint64), jnp.array([m], dtype=jnp.uint64), n
    )
    want = route_ref(base, m, n)
    for g, w in zip(got, want):
        assert_array_equal(np.asarray(g), np.asarray(w))


@settings(max_examples=15, deadline=None)
@given(base=U64, n=SIZES)
def test_route_invariants(base, n):
    m = 4096
    key, h, shard, slot = route(
        jnp.array([base], dtype=jnp.uint64), jnp.array([m], dtype=jnp.uint64), n
    )
    assert int(jnp.max(shard)) < NSHARDS
    assert int(jnp.max(slot)) < m
    # shard must be derived from the key MSBs, slot from the hash LSBs
    assert_array_equal(np.asarray(shard), np.asarray(key) >> 61)
    assert_array_equal(np.asarray(slot), np.asarray(h) & (m - 1))


@settings(max_examples=15, deadline=None)
@given(vals=st.lists(st.integers(min_value=0, max_value=NSHARDS - 1), min_size=1, max_size=512))
def test_histogram_matches_ref(vals):
    s = jnp.array(vals, dtype=jnp.uint64)
    got = shard_histogram(s)
    assert_array_equal(np.asarray(got), np.asarray(shard_histogram_ref(s)))
    assert int(jnp.sum(got)) == len(vals)


def test_block_tiled_paths_match_small_path():
    """Sizes that hit the tiled grid must agree with the single-block path."""
    n = 2 * BLOCK
    base = jnp.array([12345], dtype=jnp.uint64)
    m = jnp.array([8192], dtype=jnp.uint64)
    key, h, shard, slot = route(base, m, n)
    want = route_ref(12345, 8192, n)
    for g, w in zip((key, h, shard, slot), want):
        assert_array_equal(np.asarray(g), np.asarray(w))


def test_hash_mix_is_bijective_sample():
    """splitmix64 finalizer is a bijection — a large sample must be collision-free."""
    x = jnp.arange(1 << 16, dtype=jnp.uint64)
    h = np.asarray(hash_mix(x))
    assert len(np.unique(h)) == len(h)


@pytest.mark.parametrize("n", [4096, 65536])
def test_shard_balance(n):
    """Top-3-bit shards of scrambled keys must be near-uniform (paper §VI)."""
    key, _h, shard, _slot = route(
        jnp.array([0], dtype=jnp.uint64), jnp.array([8192], dtype=jnp.uint64), n
    )
    hist = np.asarray(shard_histogram(shard)).astype(np.float64)
    mean = n / NSHARDS
    assert np.all(np.abs(hist - mean) < 6 * np.sqrt(mean))
