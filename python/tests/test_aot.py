"""AOT path: model fns lower to valid HLO text with the expected signature."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_array_equal

from compile.aot import lower_fn
from compile.kernels.ref import route_ref, shard_histogram_ref
from compile.model import BATCH_SIZES, make_route_batch, make_route_stats


def test_route_batch_lowers_to_hlo_text():
    for n in BATCH_SIZES:
        text = lower_fn(make_route_batch(n))
        assert "HloModule" in text
        assert f"u64[{n}]" in text
        # no Mosaic custom-call may survive interpret=True lowering
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_route_stats_lowers_to_hlo_text():
    text = lower_fn(make_route_stats(BATCH_SIZES[0]))
    assert "HloModule" in text
    assert "u64[8]" in text  # histogram output


def test_route_batch_executes_like_ref():
    n = BATCH_SIZES[0]
    fn = jax.jit(make_route_batch(n))
    base = jnp.array([42], dtype=jnp.uint64)
    m = jnp.array([8192], dtype=jnp.uint64)
    got = fn(base, m)
    want = route_ref(42, 8192, n)
    for g, w in zip(got, want):
        assert_array_equal(np.asarray(g), np.asarray(w))


def test_route_stats_histogram_consistent():
    n = BATCH_SIZES[0]
    fn = jax.jit(make_route_stats(n))
    base = jnp.array([7], dtype=jnp.uint64)
    m = jnp.array([1024], dtype=jnp.uint64)
    key, h, shard, slot, hist = fn(base, m)
    assert_array_equal(np.asarray(hist), np.asarray(shard_histogram_ref(shard)))
    assert int(np.sum(np.asarray(hist))) == n
